"""Pallas flash-attention kernel vs the naive softmax oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(21)


def _qkv(b=2, sq=64, skv=64, h=4, hkv=2, dh=32, dtype=jnp.float32, key=KEY):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, dh), dtype)
    return q, k, v


def _ref(q, k, v, **kw):
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    kf = jnp.moveaxis(jnp.repeat(k, rep, 2), 2, 1).reshape(b * h, skv, dh)
    vf = jnp.moveaxis(jnp.repeat(v, rep, 2), 2, 1).reshape(b * h, skv, dh)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, dh)
    out = ref.flash_attention_ref(qf, kf, vf, **kw)
    return jnp.moveaxis(out.reshape(b, h, sq, dh), 1, 2)


SHAPES = [
    dict(b=1, sq=128, skv=128, h=2, hkv=2, dh=128),   # tile-aligned
    dict(b=2, sq=64, skv=96, h=4, hkv=2, dh=32),      # ragged everything
    dict(b=1, sq=130, skv=257, h=2, hkv=1, dh=64),    # one past tiles
    dict(b=2, sq=32, skv=512, h=8, hkv=8, dh=128),    # long kv
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("causal", [False, True])
def test_matches_ref(shape, causal):
    q, k, v = _qkv(**shape)
    scale = shape["dh"] ** -0.5
    out = ops.flash_attention(q, k, v, causal=causal, scale=scale)
    expect = _ref(q, k, v, causal=causal, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    q, k, v = _qkv(dh=64, dtype=dtype)
    out = ops.flash_attention(q, k, v, causal=True, scale=0.125)
    expect = _ref(q, k, v, causal=True, scale=0.125)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_sliding_window():
    q, k, v = _qkv(sq=128, skv=128, dh=32)
    out = ops.flash_attention(q, k, v, causal=True, window=16, scale=0.1)
    expect = _ref(q, k, v, causal=True, window=16, scale=0.1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_matches_model_chunked_attention():
    """The kernel agrees with the model's XLA lazy-softmax path."""
    from repro.models.layers import _chunk_attn_scan
    q, k, v = _qkv(b=2, sq=64, skv=64, h=4, hkv=2, dh=32)
    scale = 32 ** -0.5
    out_model = _chunk_attn_scan(q, k, v, causal=True, window=0, q_offset=0,
                                 kv_chunk=16, scale=scale)
    out_kernel = ops.flash_attention(q, k, v, causal=True, scale=scale)
    np.testing.assert_allclose(np.asarray(out_model), np.asarray(out_kernel),
                               rtol=2e-3, atol=2e-3)
