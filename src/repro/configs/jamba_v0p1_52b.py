"""Jamba v0.1 52B: Mamba+attention 1:7 interleave, MoE 16e top-2.  [arXiv:2403.19887]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", arch_type="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, head_dim=128,
    block_kind="jamba", attn_period=8, attn_offset=4, moe_period=2,
    n_experts=16, top_k=2, moe_d_ff=14336,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    source="arXiv:2403.19887",
)
