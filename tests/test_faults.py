"""Fault-injection suite: deterministic schedules, degraded-cohort math,
wire integrity under corruption, retransmit accounting, crash-safe resume.

Pins of DESIGN.md §8 ("Fault model"):

* same fault seed => the identical fault schedule, independent of the
  other rates, and the identical trajectory in ``mode="host"`` and
  ``mode="fused"``;
* a ``FaultPlan`` that draws no fault is **bit-identical** to
  ``faults=None`` for every registry scheme (the legacy code path);
* the CRC-32 trailer catches *every* single-bit flip of a frame;
* retransmitted bits booked by the engine reconcile exactly against the
  wasted bytes on the wire stream;
* a run killed at a checkpoint and resumed is bit-identical to the
  uninterrupted run (host and fused, clean and faulted);
* the staged host loop does not re-trace its round computation per round.
"""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - container has no hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core.blocks import FixedAllocation
from repro.fl import registry
from repro.fl.data import make_synthetic, partition_iid
from repro.fl.engine import FLEngine, _cohort_mean
from repro.fl.faults import FaultPlan, corrupt_copy
from repro.fl.nets import make_mlp
from repro.fl.tasks import make_cfl_task, make_mask_task
from repro.wire.frame import DIR_UP, Message, WireError

N, D = 4, 208
SCHEMES = registry.all_schemes(n=N, d=D, n_is=16, block=16, reset_period=2)
FAULT_MATRIX = registry.fault_matrix(n=N, d=D, n_is=16, block=16,
                                     reset_period=2)
PLAN = FaultPlan(drop_rate=0.3, straggler_rate=0.1, corrupt_rate=0.2, seed=5)


@pytest.fixture(scope="module")
def mask_setup():
    k = jax.random.PRNGKey(3)
    train, test = make_synthetic(k, n_train=120, n_test=60, hw=4, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, N, 30)
    net = make_mlp(in_dim=16, widths=(8,), signed_constant=True)
    task = make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                          local_epochs=1, batch_size=30)
    return task, shards


@pytest.fixture(scope="module")
def cfl_setup():
    k = jax.random.PRNGKey(4)
    train, test = make_synthetic(k, n_train=120, n_test=60, hw=4, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, N, 30)
    net = make_mlp(in_dim=16, widths=(8,))
    task, theta0 = make_cfl_task(net, jax.random.fold_in(k, 2), test.x,
                                 test.y, local_epochs=1, batch_size=30,
                                 local_lr=3e-3)
    assert int(theta0.shape[0]) == D
    return task, theta0, shards


def _setup_for(kind, mask_setup, cfl_setup):
    if kind == "mask":
        task, shards = mask_setup
        return task, shards, None
    task, theta0, shards = cfl_setup
    return task, shards, theta0


def _assert_identical(a, b):
    assert len(a["history"]) == len(b["history"])
    for ha, hb in zip(a["history"], b["history"]):
        assert set(ha) == set(hb)
        for key in ha:
            assert hb[key] == ha[key], (key, ha, hb)
    for key in a["meter"]:
        assert b["meter"][key] == a["meter"][key], key
    np.testing.assert_array_equal(np.asarray(a["theta"]),
                                  np.asarray(b["theta"]))
    np.testing.assert_array_equal(np.asarray(a["theta_hat"]),
                                  np.asarray(b["theta_hat"]))


# ---------------------------------------------------------------------------
# Schedule determinism (pure numpy, no engine).
# ---------------------------------------------------------------------------


class TestScheduleDeterminism:

    @settings(deadline=None, max_examples=8)
    @given(st.floats(min_value=0.0, max_value=0.95),
           st.floats(min_value=0.0, max_value=0.95),
           st.floats(min_value=0.0, max_value=0.9),
           st.integers(min_value=0, max_value=10_000))
    def test_same_seed_same_schedule(self, dr, sr, cr, seed):
        plan = FaultPlan(drop_rate=dr, straggler_rate=sr, corrupt_rate=cr,
                         seed=seed)
        a, b = plan.schedule(7, 5), plan.schedule(7, 5)
        np.testing.assert_array_equal(a.drop, b.drop)
        np.testing.assert_array_equal(a.straggle, b.straggle)
        np.testing.assert_array_equal(a.up_failures, b.up_failures)
        np.testing.assert_array_equal(a.dn_failures, b.dn_failures)
        np.testing.assert_array_equal(a.flip_u, b.flip_u)

    @settings(deadline=None, max_examples=8)
    @given(st.floats(min_value=0.0, max_value=0.9),
           st.floats(min_value=0.0, max_value=0.9))
    def test_rates_are_independent_dimensions(self, cr1, cr2):
        """Moving corrupt_rate must not perturb the dropout pattern."""
        base = dict(drop_rate=0.3, straggler_rate=0.2, seed=42)
        a = FaultPlan(corrupt_rate=cr1, **base).schedule(6, 5)
        b = FaultPlan(corrupt_rate=cr2, **base).schedule(6, 5)
        np.testing.assert_array_equal(a.drop, b.drop)
        np.testing.assert_array_equal(a.straggle, b.straggle)
        # and the corruption counts come from the same uniforms: the
        # higher rate dominates pointwise (monotone thresholding).
        lo, hi = (a, b) if cr1 <= cr2 else (b, a)
        assert (lo.up_failures <= hi.up_failures).all()
        assert (lo.dn_failures <= hi.dn_failures).all()

    def test_run_views_are_reproducible(self):
        sched = PLAN.schedule(5, N)
        cohort = np.stack([np.arange(N)] * 5)
        va = sched.run_views(cohort, "all")
        vb = sched.run_views(cohort, "all")
        for x, y in zip(va, vb):
            np.testing.assert_array_equal(x.contrib, y.contrib)
            np.testing.assert_array_equal(x.delivered_dn, y.delivered_dn)
            np.testing.assert_array_equal(x.up_wasted, y.up_wasted)
            assert x.all_failed == y.all_failed

    def test_trivial_plan_draws_nothing(self):
        s = FaultPlan(seed=123).schedule(10, 6)
        assert not s.drop.any() and not s.straggle.any()
        assert not s.up_failures.any() and not s.dn_failures.any()
        assert FaultPlan(seed=123).trivial


# ---------------------------------------------------------------------------
# Degraded aggregation math.
# ---------------------------------------------------------------------------


class _Ctx:
    def __init__(self, w):
        self.up_weight = w


def test_cohort_mean_full_mask_is_exact_mean():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(5, 7)),
                    dtype=jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(_cohort_mean(_Ctx(None), x)),
        np.asarray(jnp.mean(x, axis=0)))


def test_cohort_mean_renormalizes_over_survivors():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 3)),
                    dtype=jnp.float32)
    w = jnp.asarray([1.0, 0.0, 1.0, 0.0], dtype=jnp.float32)
    got = np.asarray(_cohort_mean(_Ctx(w), x))
    want = np.asarray((x[0] + x[2]) / 2.0)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # all-fail: denominator guard, finite output (the engine discards it)
    z = _cohort_mean(_Ctx(jnp.zeros(4)), x)
    assert np.isfinite(np.asarray(z)).all()


# ---------------------------------------------------------------------------
# CRC integrity: every single-bit flip of a frame must be caught.
# ---------------------------------------------------------------------------


def test_crc_catches_every_single_bit_flip():
    m = Message(direction=DIR_UP, sender=2, recipient=0xFFFF,
                payload=b"\xa5\x5a\xf0", payload_bits=20, round=9,
                scheme_id=0xBEEF)
    raw = m.to_bytes()
    assert Message.from_bytes(raw).payload_bits == 20  # clean parses
    for bitpos in range(8 * len(raw)):
        bad = corrupt_copy(raw, bitpos)
        assert bad != raw
        with pytest.raises(WireError):
            Message.from_bytes(bad)


# ---------------------------------------------------------------------------
# Trivial plan == no plan, for every registry scheme (both engine paths
# via mode="auto": fused where eligible, host otherwise).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["host", "fused"])
@pytest.mark.parametrize("name,kind,factory", SCHEMES,
                         ids=[s[0] for s in SCHEMES])
def test_trivial_plan_bit_identical(mask_setup, cfl_setup, name, kind,
                                    factory, mode):
    task, shards, theta0 = _setup_for(kind, mask_setup, cfl_setup)
    base = FLEngine(task, factory()).run(shards, theta0, rounds=2, seed=7,
                                         mode=mode)
    triv = FLEngine(task, factory()).run(shards, theta0, rounds=2, seed=7,
                                         mode=mode, faults=FaultPlan(seed=99))
    _assert_identical(base, triv)
    assert triv["faults"]["summary"]["faulty_rounds"] == 0
    assert triv["faults"]["events"] == []
    assert "faults" not in base


# ---------------------------------------------------------------------------
# Faulted host == faulted fused, one scheme per uplink family.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,kind,factory", FAULT_MATRIX,
                         ids=[s[0] for s in FAULT_MATRIX])
def test_faulted_host_fused_parity(mask_setup, cfl_setup, name, kind,
                                   factory):
    task, shards, theta0 = _setup_for(kind, mask_setup, cfl_setup)
    host = FLEngine(task, factory()).run(shards, theta0, rounds=3, seed=7,
                                         mode="host", faults=PLAN)
    fused = FLEngine(task, factory()).run(shards, theta0, rounds=3, seed=7,
                                          mode="fused", faults=PLAN)
    _assert_identical(host, fused)
    assert host["faults"] == fused["faults"]
    rep = host["faults"]
    assert rep["summary"]["faulty_rounds"] > 0  # the plan actually bites
    assert host["meter"]["retransmit_bits"] == pytest.approx(
        rep["summary"]["retransmit_bits_total"], abs=0.0)


def test_all_fail_round_falls_back(mask_setup):
    """Every client offline every round: the run aborts each round;
    the model never moves and no downlink bits are billed."""
    task, shards = mask_setup
    factory = FAULT_MATRIX[0][2]
    # rates live in [0, 1); pick (deterministically) a seed whose draw
    # at 0.95 drops every client in both rounds
    seed = next(s for s in range(1000)
                if FaultPlan(drop_rate=0.95, seed=s)
                .schedule(2, N).drop.all())
    out = FLEngine(task, factory()).run(
        shards, rounds=2, seed=7, mode="host",
        faults=FaultPlan(drop_rate=0.95, seed=seed))
    rep = out["faults"]
    assert rep["summary"]["all_failed_rounds"] == 2
    assert all(e["all_failed"] and e["survivors"] == 0
               for e in rep["events"])
    assert out["meter"]["downlink_bpp"] == 0.0
    accs = {h["acc"] for h in out["history"]}
    assert len(accs) == 1  # theta_hat frozen at its initial value


# ---------------------------------------------------------------------------
# Wire integrity under faults: retransmits reconcile against the stream.
# ---------------------------------------------------------------------------


def test_wire_faulted_audit_reconciles_and_matches_booking(mask_setup):
    task, shards = mask_setup
    factory = FAULT_MATRIX[0][2]
    wired = FLEngine(task, factory()).run(shards, rounds=3, seed=7,
                                          mode="host", wire="audit",
                                          faults=PLAN)
    rep = wired["wire"]  # reconcile raises on any divergence
    assert rep["retransmit_err_bits"] == 0.0
    assert rep["retransmit_stream_bits"] > 0
    session = wired["wire_session"]
    assert wired["meter"]["retransmit_bits"] == pytest.approx(
        session.retransmit_payload_bits)
    # the non-wire host path books the identical retransmit total (the
    # booking formula is shared, the schedule is the same seed)
    plain = FLEngine(task, factory()).run(shards, rounds=3, seed=7,
                                          mode="host", faults=PLAN)
    assert plain["meter"]["retransmit_bits"] == pytest.approx(
        wired["meter"]["retransmit_bits"])
    assert wired["faults"]["summary"]["retransmits_total"] \
        == plain["faults"]["summary"]["retransmits_total"]


# ---------------------------------------------------------------------------
# Crash-safe resume: killed at a checkpoint == uninterrupted.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["host", "fused"])
@pytest.mark.parametrize("faults", [None, PLAN],
                         ids=["clean", "faulted"])
def test_resume_matches_uninterrupted(mask_setup, tmp_path, mode, faults):
    task, shards = mask_setup
    factory = FAULT_MATRIX[0][2]
    kw = dict(rounds=4, seed=7, mode=mode, faults=faults)
    full = FLEngine(task, factory()).run(shards, **kw)

    ckdir = str(tmp_path / "ck")
    FLEngine(task, factory()).run(shards, checkpoint_dir=ckdir,
                                  checkpoint_every=2, **kw)
    # "kill" the run after round 2: drop every later checkpoint so the
    # resume genuinely restarts mid-run rather than loading the final one
    for p in glob.glob(os.path.join(ckdir, "ckpt_*.repro")):
        if not p.endswith("00000002.repro"):
            os.remove(p)
    resumed = FLEngine(task, factory()).run(shards, resume_from=ckdir, **kw)
    _assert_identical(full, resumed)
    if faults is not None:
        assert resumed["faults"] == full["faults"]


def test_resume_refuses_mismatched_config(mask_setup, tmp_path):
    task, shards = mask_setup
    factory = FAULT_MATRIX[0][2]
    ckdir = str(tmp_path / "ck")
    FLEngine(task, factory()).run(shards, rounds=2, seed=7, mode="host",
                                  checkpoint_dir=ckdir, checkpoint_every=1)
    with pytest.raises(Exception, match="config"):
        FLEngine(task, factory()).run(shards, rounds=2, seed=8, mode="host",
                                      resume_from=ckdir)


# ---------------------------------------------------------------------------
# Host-loop staging: no per-round re-trace (ROADMAP item).
# ---------------------------------------------------------------------------


def test_host_round_jit_is_cached_across_rounds(mask_setup):
    task, shards = mask_setup
    factory = FAULT_MATRIX[0][2]
    eng3 = FLEngine(task, factory())
    eng3.run(shards, rounds=3, seed=7, mode="host")
    eng6 = FLEngine(task, factory())
    eng6.run(shards, rounds=6, seed=7, mode="host")
    assert eng3.host_trace_count >= 1
    # doubling the rounds must not add traces: the staged round jit is
    # keyed by plan shape, not by round index
    assert eng6.host_trace_count == eng3.host_trace_count
