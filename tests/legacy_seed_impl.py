"""Verbatim copy of the seed's monolithic FL loops, kept ONLY as the parity
oracle for tests/test_engine_parity.py.

The production code now routes everything through the composable
Channel/Engine API (repro.fl.channels / repro.fl.engine); these functions
preserve the exact pre-refactor semantics -- per-client Python loops, full
local training under partial participation, inline bit formulas -- so the
tests can assert the new engine reproduces the old histories bit-for-bit.
Do not "fix" or modernise this file.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrc
from repro.core.bernoulli import bern_kl, clip01
from repro.core.bitmeter import BitMeter
from repro.core.blocks import AdaptiveAllocation
from repro.core.quantizers import FLOAT_BITS, sign_compress, topk_bits, topk_compress
from repro.fl.baselines import BaselineConfig
from repro.fl.federator import BiCompFLConfig, CFLConfig


def to_blocks(v: jax.Array, size: int) -> jax.Array:
    d = v.shape[-1]
    b = -(-d // size)
    pad = b * size - d
    if pad:
        v = jnp.concatenate([v, jnp.full(v.shape[:-1] + (pad,), 0.5, v.dtype)], axis=-1)
    return v.reshape(v.shape[:-1] + (b, size))


def from_blocks(m: jax.Array, d: int) -> jax.Array:
    return m.reshape(m.shape[:-2] + (-1,))[..., :d]


def _uplink_bits(n_clients, n_ul, n_blocks, n_is):
    return n_clients * n_ul * n_blocks * math.log2(n_is)


def run_bicompfl_legacy(task, shards, cfg: BiCompFLConfig) -> Dict[str, Any]:
    n = int(shards.x.shape[0])
    d = task.d
    n_dl = cfg.n_dl if cfg.n_dl is not None else n * cfg.n_ul
    base = jax.random.PRNGKey(cfg.seed)
    is_gr = cfg.variant.startswith("GR")
    meter = BitMeter(n_clients=n, d=d, broadcast_downlink_shareable=is_gr)

    theta_hat = jnp.tile(task.init_theta()[None], (n, 1))
    history: List[Dict[str, float]] = []
    adaptive = isinstance(cfg.allocation, AdaptiveAllocation)

    if cfg.participation < 1.0 and cfg.variant != "PR":
        raise ValueError("partial participation requires PR")
    n_active = max(1, int(round(cfg.participation * n)))
    rng = np.random.default_rng(cfg.seed + 17)

    log2_nis = math.log2(cfg.n_is)
    for t in range(cfg.rounds):
        kt = mrc.round_key(base, t)
        active = sorted(rng.choice(n, size=n_active, replace=False)) \
            if n_active < n else list(range(n))
        train_keys = jax.random.split(jax.random.fold_in(kt, 1), n)

        q = jax.vmap(task.local_train)(theta_hat, shards.x, shards.y, train_keys)
        q = clip01(q)

        kl_mean = np.asarray(jnp.mean(jax.vmap(bern_kl)(q, clip01(theta_hat)), axis=0))
        size, n_blocks, seg_ids, overhead = cfg.allocation.plan(kl_mean, d)

        def up_one(i, q_i, p_i):
            skey = kt if is_gr else mrc.client_key(kt, i)
            sel = jax.random.fold_in(jax.random.fold_in(kt, 2), i)
            if adaptive:
                idxs, q_hat = mrc.transmit_segments(
                    skey, sel, q_i, clip01(p_i), jnp.asarray(seg_ids),
                    n_is=cfg.n_is, n_seg=n_blocks, n_samples=cfg.n_ul)
                return idxs, q_hat
            qb, pb = to_blocks(q_i, size), to_blocks(clip01(p_i), size)
            idxs, q_hat_b = mrc.transmit_fixed(
                skey, sel, qb, pb, n_is=cfg.n_is, n_samples=cfg.n_ul,
                chunk=cfg.chunk, logw_fn=cfg.logw_fn)
            return idxs, from_blocks(q_hat_b, d)

        q_hats = []
        for i in active:
            _, q_hat_i = up_one(i, q[i], theta_hat[i])
            q_hats.append(q_hat_i)
        q_hat = jnp.stack(q_hats)
        theta_next = jnp.mean(q_hat, axis=0)

        ul_bits = _uplink_bits(len(active), cfg.n_ul, n_blocks, cfg.n_is)

        if cfg.variant == "GR":
            theta_hat = jnp.tile(theta_next[None], (n, 1))
            dl_bits = n * (n - 1) * cfg.n_ul * n_blocks * log2_nis
        elif cfg.variant == "GR-Reconst":
            skey = jax.random.fold_in(kt, 3)
            sel = jax.random.fold_in(kt, 4)
            p_common = clip01(theta_hat[0])
            if adaptive:
                _, est = mrc.transmit_segments(
                    skey, sel, theta_next, p_common, jnp.asarray(seg_ids),
                    n_is=cfg.n_is, n_seg=n_blocks, n_samples=n_dl)
            else:
                _, est_b = mrc.transmit_fixed(
                    skey, sel, to_blocks(theta_next, size), to_blocks(p_common, size),
                    n_is=cfg.n_is, n_samples=n_dl, chunk=cfg.chunk, logw_fn=cfg.logw_fn)
                est = from_blocks(est_b, d)
            theta_hat = jnp.tile(clip01(est)[None], (n, 1))
            dl_bits = n * n_dl * n_blocks * log2_nis
        elif cfg.variant == "PR":
            new_hats = list(theta_hat)
            for i in active:
                skey = jax.random.fold_in(mrc.client_key(kt, i), 3)
                sel = jax.random.fold_in(jax.random.fold_in(kt, 5), i)
                if adaptive:
                    _, est = mrc.transmit_segments(
                        skey, sel, theta_next, clip01(theta_hat[i]), jnp.asarray(seg_ids),
                        n_is=cfg.n_is, n_seg=n_blocks, n_samples=n_dl)
                else:
                    _, est_b = mrc.transmit_fixed(
                        skey, sel, to_blocks(theta_next, size),
                        to_blocks(clip01(theta_hat[i]), size),
                        n_is=cfg.n_is, n_samples=n_dl, chunk=cfg.chunk, logw_fn=cfg.logw_fn)
                    est = from_blocks(est_b, d)
                new_hats[i] = clip01(est)
            theta_hat = jnp.stack(new_hats)
            dl_bits = len(active) * n_dl * n_blocks * log2_nis
        elif cfg.variant == "PR-SplitDL":
            if adaptive:
                raise NotImplementedError("SplitDL is defined on fixed blocks")
            tb = to_blocks(theta_next, size)
            new_hats = []
            blocks_per_client = 0
            for i in range(n):
                own = np.arange(i, n_blocks, n)
                blocks_per_client = max(blocks_per_client, len(own))
                skey = jax.random.fold_in(mrc.client_key(kt, i), 3)
                sel = jax.random.fold_in(jax.random.fold_in(kt, 5), i)
                hb = to_blocks(clip01(theta_hat[i]), size)
                _, est_b = mrc.transmit_fixed(
                    skey, sel, tb[own], hb[own], n_is=cfg.n_is, n_samples=n_dl,
                    chunk=min(cfg.chunk, max(len(own), 1)), logw_fn=cfg.logw_fn)
                hb = hb.at[own].set(clip01(est_b))
                new_hats.append(from_blocks(hb, d))
            theta_hat = jnp.stack(new_hats)
            dl_bits = n * n_dl * blocks_per_client * log2_nis
        else:
            raise ValueError(cfg.variant)

        meter.add_round(ul_bits, dl_bits, overhead_bits=overhead * n)

        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            acc = task.evaluate(theta_next)
            history.append({"round": t + 1, "acc": float(acc),
                            "cum_bits": meter.total_bits,
                            "bpp_so_far": meter.total_bpp})

    return {"history": history, "meter": meter.summary(),
            "theta": theta_next, "theta_hat": theta_hat,
            "final_acc": history[-1]["acc"] if history else float("nan"),
            "max_acc": max(h["acc"] for h in history) if history else float("nan")}


def run_bicompfl_cfl_legacy(task, theta0, shards, cfg: CFLConfig) -> Dict[str, Any]:
    n = int(shards.x.shape[0])
    d = int(theta0.shape[0])
    base = jax.random.PRNGKey(cfg.seed)
    meter = BitMeter(n_clients=n, d=d, broadcast_downlink_shareable=True)
    theta = theta0
    n_blocks = -(-d // cfg.block_size)
    log2_nis = math.log2(cfg.n_is)
    history: List[Dict[str, float]] = []

    p_blocks = jnp.full((n_blocks, cfg.block_size), 0.5, jnp.float32)

    for t in range(cfg.rounds):
        kt = mrc.round_key(base, t)
        train_keys = jax.random.split(jax.random.fold_in(kt, 1), n)
        deltas = jax.vmap(task.local_train)(
            jnp.tile(theta[None], (n, 1)), shards.x, shards.y, train_keys)

        g_hats = []
        for i in range(n):
            delta = deltas[i]
            K = jnp.mean(jnp.abs(delta)) + 1e-12
            q_i = clip01(jax.nn.sigmoid(delta / K))
            sel = jax.random.fold_in(jax.random.fold_in(kt, 2), i)
            _, q_hat_b = mrc.transmit_fixed(
                kt, sel, to_blocks(q_i, cfg.block_size), p_blocks,
                n_is=cfg.n_is, n_samples=cfg.n_ul, chunk=cfg.chunk, logw_fn=cfg.logw_fn)
            q_hat = from_blocks(q_hat_b, d)
            g_hats.append((2.0 * q_hat - 1.0) * K)
        g_hat = jnp.mean(jnp.stack(g_hats), axis=0)
        theta = theta - cfg.server_lr * g_hat

        ul = _uplink_bits(n, cfg.n_ul, n_blocks, cfg.n_is) + 32 * n
        dl = n * (n - 1) * cfg.n_ul * n_blocks * log2_nis + 32 * n * (n - 1)
        meter.add_round(ul, dl)

        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            acc = task.evaluate(theta)
            history.append({"round": t + 1, "acc": float(acc),
                            "cum_bits": meter.total_bits})

    return {"history": history, "meter": meter.summary(), "theta": theta,
            "final_acc": history[-1]["acc"] if history else float("nan"),
            "max_acc": max(h["acc"] for h in history) if history else float("nan")}


def run_baseline_legacy(task, theta0, shards, cfg: BaselineConfig) -> Dict[str, Any]:
    n = int(shards.x.shape[0])
    d = int(theta0.shape[0])
    base = jax.random.PRNGKey(cfg.seed)
    scheme = cfg.scheme.lower()
    meter = BitMeter(n_clients=n, d=d,
                     broadcast_downlink_shareable=(scheme != "m3"))

    theta = theta0
    theta_hat = jnp.tile(theta0[None], (n, 1))
    e_up = jnp.zeros((n, d))
    e_down = jnp.zeros((d,))
    k_m3 = max(d // n, 1)
    history: List[Dict[str, float]] = []

    def sign2(v):
        c1 = sign_compress(v)
        c2 = sign_compress(v - c1)
        return c1 + c2

    for t in range(cfg.rounds):
        kt = jax.random.fold_in(base, t)
        train_keys = jax.random.split(jax.random.fold_in(kt, 1), n)
        deltas = jax.vmap(task.local_train)(theta_hat, shards.x, shards.y, train_keys)

        ul_bits = dl_bits = 0.0
        if scheme == "fedavg":
            agg = jnp.mean(deltas, axis=0)
            theta = theta - cfg.server_lr * agg
            theta_hat = jnp.tile(theta[None], (n, 1))
            ul_bits = n * d * FLOAT_BITS
            dl_bits = n * d * FLOAT_BITS
        elif scheme in ("memsgd", "cser"):
            c = jax.vmap(sign_compress)(deltas + e_up)
            e_up = deltas + e_up - c
            theta = theta - cfg.server_lr * jnp.mean(c, axis=0)
            theta_hat = jnp.tile(theta[None], (n, 1))
            ul_bits = n * (d + FLOAT_BITS)
            dl_bits = n * d * FLOAT_BITS
            if scheme == "cser" and (t + 1) % cfg.reset_period == 0:
                theta = theta - cfg.server_lr * jnp.mean(e_up, axis=0)
                e_up = jnp.zeros_like(e_up)
                theta_hat = jnp.tile(theta[None], (n, 1))
                ul_bits += n * d * FLOAT_BITS
                dl_bits += n * d * FLOAT_BITS
        elif scheme in ("doublesqueeze", "neolithic", "liec"):
            comp = sign2 if scheme == "neolithic" else sign_compress
            bits_per = 2.0 if scheme == "neolithic" else 1.0
            c = jax.vmap(comp)(deltas + e_up)
            e_up = deltas + e_up - c
            agg = jnp.mean(c, axis=0) + e_down
            c_s = comp(agg)
            e_down = agg - c_s
            theta = theta - cfg.server_lr * c_s
            theta_hat = theta_hat - cfg.server_lr * c_s[None, :]
            ul_bits = n * (bits_per * d + FLOAT_BITS * (2 if scheme == "neolithic" else 1))
            dl_bits = n * (bits_per * d + FLOAT_BITS * (2 if scheme == "neolithic" else 1))
            if scheme == "liec" and (t + 1) % cfg.reset_period == 0:
                theta = theta - cfg.server_lr * (jnp.mean(e_up, axis=0) + e_down)
                e_up = jnp.zeros_like(e_up)
                e_down = jnp.zeros_like(e_down)
                theta_hat = jnp.tile(theta[None], (n, 1))
                ul_bits += n * d * FLOAT_BITS
                dl_bits += n * d * FLOAT_BITS
        elif scheme == "m3":
            c = jax.vmap(lambda v: topk_compress(v, k_m3))(deltas + e_up)
            e_up = deltas + e_up - c
            theta = theta - cfg.server_lr * jnp.mean(c, axis=0)
            new_hat = []
            for i in range(n):
                lo = i * k_m3
                hi = d if i == n - 1 else min((i + 1) * k_m3, d)
                sl = theta_hat[i].at[lo:hi].set(theta[lo:hi])
                new_hat.append(sl)
            theta_hat = jnp.stack(new_hat)
            ul_bits = n * topk_bits(d, k_m3)
            dl_bits = n * (d / n) * FLOAT_BITS
        else:
            raise ValueError(scheme)

        meter.add_round(ul_bits, dl_bits)
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            acc = task.evaluate(theta)
            history.append({"round": t + 1, "acc": float(acc),
                            "cum_bits": meter.total_bits})

    return {"history": history, "meter": meter.summary(), "theta": theta,
            "final_acc": history[-1]["acc"] if history else float("nan"),
            "max_acc": max(h["acc"] for h in history) if history else float("nan")}
