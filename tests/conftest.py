"""Shared pytest fixtures.

The container's CPU JIT accumulates compiled dylibs across the whole
session and eventually dies with ``LLVM compilation error: Cannot allocate
memory`` (~200 distinct jits on this 1-core box).  Clearing the jax
compilation caches between test modules keeps the full suite inside the
limit without re-jitting within a module.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
