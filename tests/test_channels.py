"""Unit tests for the composable channels: bit accounting, EF invariants,
flush semantics, and a scheme combination the seed loops could not express.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocks import FixedAllocation
from repro.core.quantizers import FLOAT_BITS, topk_bits
from repro.fl import channels as ch
from repro.fl.data import make_synthetic, partition_iid
from repro.fl.engine import EngineSpec, FLEngine, MeanModelAggregator
from repro.fl.nets import make_mlp
from repro.fl.tasks import make_mask_task

KEY = jax.random.PRNGKey(0)
N, D = 4, 96


def _ctx(n=N, d=D, active=None, size=32, n_blocks=None):
    active = np.arange(n) if active is None else np.asarray(active)
    n_blocks = -(-d // size) if n_blocks is None else n_blocks
    plan = ch.BlockPlan(size=size, n_blocks=n_blocks, seg_ids=None,
                        overhead_bits=0.0)
    return ch.RoundContext(t=0, key=KEY, n_clients=n, d=d, active=active,
                           plan=plan)


def _payload(n=N, d=D):
    return jax.random.normal(KEY, (n, d))


class TestMRCChannels:
    def test_fixed_uplink_bits_and_shape(self):
        ctx = _ctx()
        q = jax.random.uniform(KEY, (N, D), minval=0.2, maxval=0.8)
        p = jnp.full((N, D), 0.5)
        chan = ch.MRCFixedChannel(n_is=16, n_samples=2, shared=True)
        q_hat, bits = chan.transmit(ctx, q, p)
        assert q_hat.shape == (N, D)
        assert bits == N * 2 * ctx.plan.n_blocks * math.log2(16)
        # estimates are means of {0,1} samples
        assert float(q_hat.min()) >= 0.0 and float(q_hat.max()) <= 1.0

    def test_fixed_uplink_partial_cohort_bills_active_only(self):
        ctx = _ctx(active=[0, 2])
        q = jax.random.uniform(KEY, (2, D), minval=0.2, maxval=0.8)
        p = jnp.full((2, D), 0.5)
        chan = ch.MRCFixedChannel(n_is=16, n_samples=1, shared=False)
        q_hat, bits = chan.transmit(ctx, q, p)
        assert q_hat.shape == (2, D)
        assert bits == 2 * ctx.plan.n_blocks * math.log2(16)

    def test_private_downlink_updates_only_active(self):
        ctx = _ctx(active=[1, 3])
        theta_hat = jnp.full((N, D), 0.5)
        update = ch.ServerUpdate(theta=jax.random.uniform(KEY, (D,)))
        chan = ch.MRCPrivateDownlink(n_is=16, n_samples=2)
        res = chan.distribute(ctx, update, jnp.zeros(D), theta_hat)
        assert res.bits == 2 * 2 * ctx.plan.n_blocks * math.log2(16)
        th = np.asarray(res.theta_hat)
        np.testing.assert_array_equal(th[0], 0.5 * np.ones(D))
        np.testing.assert_array_equal(th[2], 0.5 * np.ones(D))
        assert not np.array_equal(th[1], 0.5 * np.ones(D))

    def test_split_downlink_bits_divided_by_n(self):
        ctx = _ctx()
        update = ch.ServerUpdate(theta=jax.random.uniform(KEY, (D,)))
        full = ch.MRCPrivateDownlink(n_is=16, n_samples=4)
        split = ch.SplitBlockDownlink(n_is=16, n_samples=4)
        theta_hat = jnp.full((N, D), 0.5)
        rf = full.distribute(ctx, update, jnp.zeros(D), theta_hat)
        rs = split.distribute(ctx, update, jnp.zeros(D), theta_hat)
        # each client receives ceil(B/n) of the B blocks
        max_len = -(-ctx.plan.n_blocks // N)
        assert rs.bits == N * 4 * max_len * math.log2(16)
        assert rs.bits < rf.bits

    def test_index_relay_bits(self):
        ctx = _ctx()
        update = ch.ServerUpdate(theta=jnp.full((D,), 0.25))
        chan = ch.IndexRelayDownlink(n_is=16, n_samples=3, side_info_bits=32)
        res = chan.distribute(ctx, update, jnp.zeros(D), jnp.zeros((N, D)))
        expect = N * (N - 1) * (3 * ctx.plan.n_blocks * math.log2(16) + 32)
        assert res.bits == expect
        np.testing.assert_array_equal(np.asarray(res.theta_hat),
                                      np.full((N, D), 0.25))


class TestBaselineChannels:
    def test_dense_bits(self):
        ctx = _ctx()
        out, bits = ch.DenseChannel().transmit(ctx, _payload(), None)
        assert bits == N * D * FLOAT_BITS
        res = ch.DenseChannel().distribute(
            ctx, ch.ServerUpdate(theta=jnp.ones(D)), jnp.zeros(D),
            jnp.zeros((N, D)))
        assert res.bits == N * D * FLOAT_BITS

    @pytest.mark.parametrize("passes", [1, 2])
    def test_sign_ef_invariant_and_bits(self, passes):
        ctx = _ctx()
        chan = ch.SignEFChannel(passes=passes)
        v = _payload()
        c, bits = chan.transmit(ctx, v, None)
        assert bits == N * passes * (D + FLOAT_BITS)
        # EF invariant: compressed + residual == input (+ zero initial memory)
        np.testing.assert_allclose(np.asarray(c + chan._e), np.asarray(v),
                                   rtol=1e-6, atol=1e-6)

    def test_sign_ef_flush_returns_mean_residual(self):
        ctx = _ctx()
        chan = ch.SignEFChannel()
        v = _payload()
        c, _ = chan.transmit(ctx, v, None)
        resid = np.asarray(jnp.mean(v - c, axis=0))
        r, bits = chan.flush(N, D)
        np.testing.assert_allclose(np.asarray(r), resid, rtol=1e-6, atol=1e-6)
        assert bits == N * D * FLOAT_BITS
        # memory cleared
        np.testing.assert_array_equal(np.asarray(chan._e), np.zeros((N, D)))

    def test_topk_ef_bits(self):
        ctx = _ctx()
        k = D // N
        chan = ch.TopKEFChannel(k=k)
        c, bits = chan.transmit(ctx, _payload(), None)
        assert bits == N * topk_bits(D, k)
        assert int(jnp.sum(c[0] != 0)) <= k

    def test_slice_downlink_disjoint(self):
        ctx = _ctx()
        th = jnp.arange(D, dtype=jnp.float32)
        res = ch.SliceDownlink().distribute(
            ctx, ch.ServerUpdate(theta=th), jnp.zeros(D),
            jnp.full((N, D), -1.0))
        assert res.bits == N * (D / N) * FLOAT_BITS
        got = np.asarray(res.theta_hat)
        k = D // N
        for i in range(N):
            hi = D if i == N - 1 else (i + 1) * k
            np.testing.assert_array_equal(got[i, i * k:hi],
                                          np.arange(i * k, hi))
            assert np.all(got[i, :i * k] == -1.0)

    def test_ef_uplink_rejects_partial_participation(self):
        ctx = _ctx(active=[0, 1])
        with pytest.raises(ValueError):
            ch.SignEFChannel().transmit(ctx, _payload(2), None)


def test_engine_resets_ef_state_between_runs():
    """Re-running one spec must not leak error-feedback memory."""
    from repro.fl.registry import baseline_spec
    from repro.fl.tasks import make_cfl_task
    k = jax.random.PRNGKey(2)
    train, test = make_synthetic(k, n_train=160, n_test=80, hw=5, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, 2, 80)
    net = make_mlp(in_dim=25, widths=(16,))
    task, theta0 = make_cfl_task(net, jax.random.fold_in(k, 2), test.x,
                                 test.y, local_epochs=1, batch_size=40)
    spec = baseline_spec("doublesqueeze", n=2, d=int(theta0.shape[0]))
    eng = FLEngine(task, spec)
    first = eng.run(shards, theta0, rounds=2, seed=0)
    second = eng.run(shards, theta0, rounds=2, seed=0)
    np.testing.assert_array_equal(np.asarray(first["theta"]),
                                  np.asarray(second["theta"]))
    assert first["history"] == second["history"]


class TestNovelComposition:
    """MRC uplink + sign-EF downlink: inexpressible in the seed's loops."""

    def test_mrc_up_sign_ef_down_end_to_end(self):
        k = jax.random.PRNGKey(9)
        train, test = make_synthetic(k, n_train=240, n_test=120, hw=6,
                                     noise=0.5)
        shards = partition_iid(jax.random.fold_in(k, 1), train, 3, 80)
        net = make_mlp(in_dim=36, widths=(32,), signed_constant=True)
        task = make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                              local_epochs=1, batch_size=40)
        spec = EngineSpec(
            uplink=ch.MRCFixedChannel(n_is=16, n_samples=1, shared=True),
            downlink=ch.SignEFChannel(),
            aggregator=MeanModelAggregator(),
            allocation=FixedAllocation(64),
            name="mrc-up+sign-ef-down")
        out = FLEngine(task, spec).run(shards, rounds=3, seed=0)
        assert np.isfinite(out["final_acc"])
        d = task.d
        n_blocks = -(-d // 64)
        rounds = 3
        m = out["meter"]
        # MRC uplink bits + sign-EF downlink bits, both exact
        assert m["uplink_bpp"] * (3 * d * rounds) == pytest.approx(
            3 * n_blocks * math.log2(16) * rounds)
        assert m["downlink_bpp"] * (3 * d * rounds) == pytest.approx(
            3 * (d + FLOAT_BITS) * rounds)
