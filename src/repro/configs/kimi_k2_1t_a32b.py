"""Kimi K2: trillion-parameter MoE, 384 experts top-8.  [arXiv:2501.kimi2]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", arch_type="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, head_dim=128,
    n_experts=384, top_k=8, moe_d_ff=2048, shared_experts=1,
    first_dense_layers=1,
    source="arXiv:2501.kimi2 (Kimi K2 paper-table config)",
)
