"""Block-allocation strategies for MRC (paper Section 3 / Appendix E).

* ``FixedAllocation``       -- constant block size d/B across rounds.
* ``AdaptiveAvgAllocation`` -- the paper's low-complexity proposal: keep equal
  block sizes but re-optimize the (single) size each round so that the
  *average* KL per block tracks the target log(n_is); only one size needs to
  be transmitted (log2(b_max) bits when it changes).
* ``AdaptiveAllocation``    -- Isik et al. (2024): variable block boundaries
  with (approximately) equal KL mass per block; boundaries are transmitted.

To keep JIT shapes static, adaptive sizes are quantized to powers of two in
[min_block, max_block]; AdaptiveAllocation represents boundaries through a
segment-id vector with a static maximum number of segments.

Bucketed device-resident planning
---------------------------------
The host control plane (``plan``) recomputes exact block boundaries each
round from that round's KL profile -- data-dependent shapes, so the fused
``lax.scan`` engine cannot compile it.  The bucket API is the traceable
counterpart: every adaptive allocation additionally exposes

* ``bucket_plans(d)``  -- a small *static* set of precompiled
  :class:`BlockPlan` templates (one ``lax.switch`` branch each);
* ``select_bucket(stats, d)``  -- pure-jnp selection of the branch index
  from the round's on-device KL statistics (traced int32);
* ``finalize_plan(template, stats, d)`` -- fills the selected template's
  data-dependent pieces (traced segment ids, traced billable block count)
  without changing any shape.

``AdaptiveAvgAllocation``'s bucket set is exactly its pow2 size grid, so
bucketing loses nothing (the exact plan *is* a bucket).  For
``AdaptiveAllocation`` the requested block count is rounded **down** onto a
geometric grid -- conservative by construction: the bucketed plan never
books more bits than the exact plan's budget plus the allocation's declared
``bucket_overhead_bits`` (tests/test_allocation.py pins both properties).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .bernoulli import bern_kl


def _pad_to(d: int, block: int) -> int:
    return -(-d // block) * block


@dataclass(frozen=True)
class BlockPlan:
    """One round's block-allocation decision.

    Host control plane: ``seg_ids`` is a numpy array and ``overhead_bits`` /
    ``billable_blocks`` are Python numbers.  Fused control plane: the plan is
    built inside the scanned round body, so ``seg_ids``, ``overhead_bits``
    and ``billable_blocks`` may be *traced* values -- only ``size`` and
    ``n_blocks`` (which fix device shapes) must stay static.
    """

    size: Optional[int]            # fixed block size (None for segment codec)
    n_blocks: int                  # static segment capacity (shapes)
    seg_ids: Any                   # per-parameter segment ids (adaptive only)
    overhead_bits: Any             # side information per client (may be traced)
    billable_blocks: Any = None    # actually-transmitted blocks (may be traced)

    @property
    def adaptive(self) -> bool:
        return self.seg_ids is not None

    @property
    def billable(self):
        """Blocks that cross the wire: ``n_blocks`` unless the (traced)
        actual segment count says fewer.  Channels must bill this, not
        ``n_blocks`` -- it is what makes channel bits traced values under
        bucketed adaptive plans."""
        return self.n_blocks if self.billable_blocks is None \
            else self.billable_blocks


@dataclass
class FixedAllocation:
    block_size: int = 256

    name = "Fixed"
    needs_kl = False  # plan() ignores the KL profile; lets the engine skip it
    static_plan = True  # round-independent: eligible for the fused scan path

    def blocks_for(self, d: int) -> int:
        return _pad_to(d, self.block_size) // self.block_size

    def plan(self, kl_per_param: Optional[np.ndarray], d: int):
        """Return (block_size, n_blocks, seg_ids=None, overhead_bits)."""
        return self.block_size, self.blocks_for(d), None, 0.0

    # -- wire codec: the plan is static config, zero bits cross the wire --
    def encode_plan(self, plan: "BlockPlan", w) -> None:
        pass

    def decode_plan(self, r, d: int) -> "BlockPlan":
        return BlockPlan(size=self.block_size, n_blocks=self.blocks_for(d),
                         seg_ids=None, overhead_bits=0.0)


@dataclass
class AdaptiveAvgAllocation:
    """Equal-size blocks, size re-tuned each round from the average KL.

    Target: per-block KL (in nats) ~ target_ratio * log(n_is); block sizes
    are powers of two in [min_block, max_block]. The size update costs
    log2(log2(max_block)) ~ a few bits; we book ceil(log2(max_block)) bits.
    """

    n_is: int = 256
    target_ratio: float = 1.0
    min_block: int = 32
    max_block: int = 4096

    name = "Adaptive-Avg"
    needs_kl = True
    static_plan = False       # per-round size retuning ...
    needs_profile = False     # ... but only the *mean* KL is consumed
    bucket_overhead_bits = 0.0  # buckets == the exact pow2 plan space

    def plan(self, kl_per_param: Optional[np.ndarray], d: int):
        if kl_per_param is None:
            size = self.min_block * 8
        else:
            mean_kl = float(np.mean(kl_per_param)) + 1e-12
            target = self.target_ratio * math.log(self.n_is)
            size = target / mean_kl
        size = 2 ** int(np.clip(np.round(np.log2(max(size, 1))),
                                math.log2(self.min_block), math.log2(self.max_block)))
        n_blocks = _pad_to(d, size) // size
        return size, n_blocks, None, math.ceil(math.log2(self.max_block))

    # -- wire codec: the pow2 size exponent, exactly the booked overhead --
    def encode_plan(self, plan: "BlockPlan", w) -> None:
        from repro.wire import codecs as wcodecs
        wcodecs.put_plan_avg(w, plan.size, self.max_block)

    def decode_plan(self, r, d: int) -> "BlockPlan":
        from repro.wire import codecs as wcodecs
        size = wcodecs.get_plan_avg(r, self.max_block)
        return BlockPlan(size=size, n_blocks=_pad_to(d, size) // size,
                         seg_ids=None,
                         overhead_bits=math.ceil(math.log2(self.max_block)))

    # -- bucketed (fused) control plane -----------------------------------

    def bucket_sizes(self) -> Tuple[int, ...]:
        lo = int(math.log2(self.min_block))
        hi = int(math.log2(self.max_block))
        return tuple(2 ** k for k in range(lo, hi + 1))

    def bucket_plans(self, d: int):
        overhead = float(math.ceil(math.log2(self.max_block)))
        return [BlockPlan(size=s, n_blocks=_pad_to(d, s) // s, seg_ids=None,
                          overhead_bits=overhead)
                for s in self.bucket_sizes()]

    def select_bucket(self, stats, d: int):
        """Traced bucket index from the on-device mean KL; mirrors ``plan``
        (same target / pow2 rounding), so the selected bucket *is* the exact
        plan up to f32-vs-f64 rounding of the mean."""
        mean_kl = stats["total"] / d + 1e-12
        target = self.target_ratio * math.log(self.n_is)
        size = jnp.maximum(target / mean_kl, 1.0)
        lo = math.log2(self.min_block)
        hi = math.log2(self.max_block)
        k = jnp.clip(jnp.round(jnp.log2(size)), lo, hi)
        return (k - lo).astype(jnp.int32)

    def finalize_plan(self, template: BlockPlan, stats, d: int) -> BlockPlan:
        return template  # nothing data-dependent beyond the size choice


@dataclass
class AdaptiveAllocation:
    """Variable boundaries with equal KL mass per block (Isik et al. 2024).

    Number of blocks B is chosen so that total KL / B ~ log(n_is); boundaries
    are found by cumulative-KL binning. Overhead: B * ceil(log2(max_block))
    bits to transmit the block intervals (paper, Appendix E).

    ``buckets`` (optional) pins the fused path's block-count grid; by
    default a geometric ratio-2 grid from ``min_blocks`` up to the cap
    ``max(min_blocks, d // 8)`` is used.  The requested count rounds *down*
    onto the grid (conservative: never more bits than the exact plan).
    """

    n_is: int = 256
    target_ratio: float = 1.0
    min_blocks: int = 4
    max_block: int = 4096
    buckets: Optional[Tuple[int, ...]] = None

    name = "Adaptive"
    needs_kl = True
    static_plan = False
    needs_profile = True      # cumulative-KL binning needs the full profile
    bucket_overhead_bits = 0.0  # floor-rounding can only shrink the budget

    def _cap(self, d: int) -> int:
        return max(self.min_blocks, d // 8)

    def plan(self, kl_per_param: Optional[np.ndarray], d: int):
        if kl_per_param is None:
            # Cold start: fall back to fixed 256-size blocks.
            size = 256
            n_blocks = _pad_to(d, size) // size
            seg = np.minimum(np.arange(d) // size, n_blocks - 1)
            return None, n_blocks, seg.astype(np.int32), 0.0
        total = float(np.sum(kl_per_param)) + 1e-12
        target = self.target_ratio * math.log(self.n_is)
        n_blocks = max(self.min_blocks, int(math.ceil(total / target)))
        n_blocks = min(n_blocks, self._cap(d))
        cum = np.cumsum(np.asarray(kl_per_param, dtype=np.float64))
        # boundary so each block holds ~ total/n_blocks KL mass
        edges = np.searchsorted(cum, np.linspace(0, total, n_blocks + 1)[1:-1])
        seg = np.zeros(d, dtype=np.int32)
        seg[edges] += 1
        seg = np.cumsum(seg).astype(np.int32)
        overhead = (int(seg.max()) + 1) * math.ceil(math.log2(self.max_block))
        return None, int(seg.max()) + 1, seg, float(overhead)

    # -- wire codec: one (length - 1) field per billable segment ----------
    # The cold-start plan (no KL profile yet) books zero overhead, so it
    # writes zero bits; the decoder detects the empty header and rebuilds
    # the deterministic fixed-256 fallback from ``d`` alone.

    def _cold_plan(self, d: int) -> "BlockPlan":
        size = 256
        n_blocks = _pad_to(d, size) // size
        seg = np.minimum(np.arange(d) // size, n_blocks - 1).astype(np.int32)
        return BlockPlan(size=None, n_blocks=n_blocks, seg_ids=seg,
                         overhead_bits=0.0)

    def encode_plan(self, plan: "BlockPlan", w) -> None:
        from repro.wire import codecs as wcodecs
        if plan.overhead_bits:
            wcodecs.put_plan_segments(w, plan.seg_ids, self.max_block)

    def decode_plan(self, r, d: int) -> "BlockPlan":
        from repro.wire import codecs as wcodecs
        if r.bits_left == 0:
            return self._cold_plan(d)
        seg = wcodecs.get_plan_segments(r, d, self.max_block)
        n_seg = int(seg[-1]) + 1
        overhead = n_seg * math.ceil(math.log2(self.max_block))
        return BlockPlan(size=None, n_blocks=n_seg, seg_ids=seg,
                         overhead_bits=float(overhead))

    # -- bucketed (fused) control plane -----------------------------------

    def bucket_grid(self, d: int) -> Tuple[int, ...]:
        """Block-count grid; always contains ``min_blocks`` so the floor
        rounding in ``select_bucket`` has a conservative anchor -- without
        it, an explicit ``buckets=`` set starting above the exact count
        would silently round *up* and out-bill the exact plan."""
        cap = self._cap(d)
        if self.buckets is not None:
            grid = sorted({int(np.clip(b, self.min_blocks, cap))
                           for b in self.buckets} | {self.min_blocks})
            return tuple(grid)
        grid = []
        b = self.min_blocks
        while b < cap:
            grid.append(b)
            b *= 2
        grid.append(cap)
        return tuple(grid)

    def bucket_plans(self, d: int):
        overhead = float(math.ceil(math.log2(self.max_block)))
        return [BlockPlan(size=None, n_blocks=nb, seg_ids=None,
                          overhead_bits=nb * overhead)
                for nb in self.bucket_grid(d)]

    def select_bucket(self, stats, d: int):
        """Traced index of the largest bucket <= the exact block count."""
        total = stats["total"] + 1e-12
        target = self.target_ratio * math.log(self.n_is)
        nb = jnp.clip(jnp.ceil(total / target), self.min_blocks, self._cap(d))
        grid = jnp.asarray(self.bucket_grid(d), jnp.float32)
        idx = jnp.searchsorted(grid, nb.astype(jnp.float32), side="right") - 1
        return jnp.clip(idx, 0, grid.shape[0] - 1).astype(jnp.int32)

    def finalize_plan(self, template: BlockPlan, stats, d: int) -> BlockPlan:
        """Equal-KL-mass binning into the bucket's (static) block count.

        Mirrors ``plan`` with jnp in place of numpy: duplicate bin edges
        collapse (``.at[edges].set(1)`` == numpy's buffered fancy ``+= 1``),
        so the traced actual segment count ``seg[-1] + 1`` -- what crosses
        the wire and what the channels bill -- can be below the template's
        static capacity, exactly like the host plan's ``seg.max() + 1``.
        """
        klp = stats["profile"]
        nb = template.n_blocks
        cum = jnp.cumsum(klp)
        total = cum[-1] + 1e-12
        targets = total * jnp.arange(1, nb, dtype=jnp.float32) / nb
        edges = jnp.clip(jnp.searchsorted(cum, targets), 0, d - 1)
        seg = jnp.cumsum(jnp.zeros(d, jnp.int32).at[edges].set(1))
        billable = seg[-1] + 1
        overhead = billable * math.ceil(math.log2(self.max_block))
        return BlockPlan(size=None, n_blocks=nb, seg_ids=seg,
                         overhead_bits=overhead, billable_blocks=billable)


def kl_per_param(q, p) -> np.ndarray:
    return np.asarray(bern_kl(q, p))
