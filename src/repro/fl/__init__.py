"""Federated-learning runtime: channels, engine, tasks, data, wrappers."""
from . import (baselines, channels, data, engine, federator, nets,  # noqa: F401
               registry, tasks)
