"""Render dryrun_*.json into the EXPERIMENTS.md roofline markdown tables.

    PYTHONPATH=src python -m benchmarks.roofline_md [dryrun_1pod.json ...]
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(x):
    if x is None:
        return "?"
    for u in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{u}"
        x /= 1024
    return f"{x:.1f}PB"


def emit(path: str) -> None:
    rows = json.load(open(path))
    chips = 512 if rows and rows[0]["multi_pod"] else 256
    print(f"\n### {path}  ({chips} chips)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant | "
          "bound_s | args/dev | temp/dev | MODEL_F/HLO_F | note |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "skip":
            print(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | - "
                  f"| - | skip: {r['reason']} |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | - "
                  f"| - | **FAIL** {r.get('error', '')[:60]} |")
            continue
        rl = r["roofline"]
        mf = r["model_flops_6nd"] / chips / max(rl["flops_per_dev"], 1e-9)
        mem = r["memory"]
        note = r.get("optimizer", "")
        if r.get("microbatches"):
            note += f" mb={r['microbatches']}"
        print(f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4f} | "
              f"{rl['memory_s']:.4f} | {rl['collective_s']:.4f} | "
              f"{rl['dominant']} | {rl['bound_s']:.4f} | "
              f"{fmt_bytes(mem['argument_bytes'])} | "
              f"{fmt_bytes(mem['temp_bytes'])} | {mf:.2f} | {note} |")


if __name__ == "__main__":
    for p in sys.argv[1:] or ("dryrun_1pod.json", "dryrun_2pod.json"):
        emit(p)
