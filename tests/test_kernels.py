"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(2)

LOGW_SHAPES = [
    (1, 16, 32),       # tiny, everything padded
    (3, 100, 70),      # ragged both dims
    (2, 128, 128),     # exactly tile-aligned
    (4, 256, 256),     # multi-tile
    (1, 129, 257),     # one past alignment
    (7, 64, 300),
]


@pytest.mark.parametrize("shape", LOGW_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mrc_logw_matches_ref(shape, dtype):
    nb, nis, s = shape
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = (jax.random.uniform(k1, (nb, nis, s)) < 0.5).astype(dtype)
    a = jax.random.normal(k2, (nb, s), dtype)
    b = jax.random.normal(k3, (nb, s), dtype)
    out = ops.mrc_logw(x, a, b)
    expect = ref.mrc_logw_ref(x.astype(jnp.float32), a.astype(jnp.float32),
                              b.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol * s)


KL_SHAPES = [(1, 16), (5, 100), (2, 128), (3, 256), (4, 300), (16, 129)]


@pytest.mark.parametrize("shape", KL_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bernoulli_kl_matches_ref(shape, dtype):
    nb, s = shape
    q = jax.random.uniform(KEY, (nb, s), minval=0.05, maxval=0.95).astype(dtype)
    p = jax.random.uniform(jax.random.fold_in(KEY, 1), (nb, s),
                           minval=0.05, maxval=0.95).astype(dtype)
    out = ops.bernoulli_kl(q, p)
    expect = ref.bernoulli_kl_ref(q.astype(jnp.float32), p.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(1, 16), (3, 100), (4, 700), (10, 1536)])
def test_bernoulli_kl_total_matches_mean_reduction(shape):
    """The engine-facing profile statistic: mean-over-clients total KL via
    the Pallas streaming reduction == the plain elementwise reduction
    (padding rows carry q == p == 0.5, zero KL, so the pad is exact)."""
    n, d = shape
    q = jax.random.uniform(KEY, (n, d), minval=0.05, maxval=0.95)
    p = jax.random.uniform(jax.random.fold_in(KEY, 2), (n, d),
                           minval=0.05, maxval=0.95)
    out = ops.bernoulli_kl_total(q, p)
    expect = jnp.mean(ref.bernoulli_kl_ref(q, p))  # mean of per-client totals
    np.testing.assert_allclose(float(out), float(expect), rtol=1e-5)


def test_logw_zero_padding_exact():
    """Padded entries contribute exactly zero -- unpadded prefix identical."""
    nb, nis, s = 2, 60, 50
    x = (jax.random.uniform(KEY, (nb, nis, s)) < 0.3).astype(jnp.float32)
    a = jax.random.normal(jax.random.fold_in(KEY, 1), (nb, s))
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (nb, s))
    np.testing.assert_allclose(
        np.asarray(ops.mrc_logw(x, a, b)),
        np.asarray(ref.mrc_logw_ref(x, a, b)), rtol=1e-5, atol=1e-4)


def test_kernels_under_jit_and_grad_free():
    """The ops wrappers are jit-stable (no retraces explode, shapes static)."""
    x = (jax.random.uniform(KEY, (2, 64, 96)) < 0.5).astype(jnp.float32)
    a = jnp.ones((2, 96))
    b = jnp.zeros((2, 96))
    f = jax.jit(lambda x, a, b: ops.mrc_logw(x, a, b))
    out1 = f(x, a, b)
    out2 = f(x + 0, a, b)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
