"""End-to-end driver: train a ~100M-parameter qwen3-family model.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--bicompfl]

Uses the real production stack -- config system, sharded Trainer (pjit on
whatever devices exist; a degenerate 1x1 mesh on this CPU container),
synthetic Markov token pipeline, checkpointing.  ``--bicompfl`` turns on the
paper's stochastic-sign gradient compression inside the train step.

~100M config: 12 layers, d_model 768, 12 heads (GQA kv=4), d_ff 2048,
vocab 8192 => ~98M parameters.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.data import batches_for
from repro.launch.mesh import make_host_mesh
from repro.launch.train import Trainer
from repro.models.config import ArchConfig

CFG_100M = ArchConfig(
    name="repro-100m", arch_type="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab=8192, head_dim=64,
    qk_norm=True, dtype="float32", remat=False,
    source="examples/train_100m.py (qwen3-family, scaled)",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--bicompfl", action="store_true",
                    help="stochastic-sign + MRC-style gradient compression")
    ap.add_argument("--ckpt", default="/tmp/repro_100m.ckpt")
    args = ap.parse_args()

    cfg = CFG_100M
    n = cfg.params_count()
    print(f"arch {cfg.name}: {n/1e6:.0f}M params, vocab {cfg.vocab}")

    trainer = Trainer(cfg, mesh=make_host_mesh(), lr=args.lr,
                      microbatches=1, kv_chunk=args.seq,
                      grad_compression="stochastic_sign" if args.bicompfl else None)

    data = batches_for(cfg, args.batch, args.seq, seed=0)
    t0 = time.time()
    losses = []
    for step, batch in enumerate(data):
        if step >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss = trainer.step(batch)
        losses.append(loss)
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tok_s = (step + 1) * args.batch * args.seq / dt
            print(f"step {step:4d}  loss {loss:8.4f}  ({tok_s:,.0f} tok/s)",
                  flush=True)

    assert losses[-1] < losses[0], "loss did not decrease"
    checkpoint.save(args.ckpt, trainer.params, step=args.steps)
    print(f"saved checkpoint to {args.ckpt}")
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
