"""Mixture-of-Experts FFN with expert-parallel sharding.

GShard-style capacity dispatch, adapted to the TPU mesh:

* expert weights are sharded 2-D: experts over ``model`` and the FFN hidden
  dim over ``data`` (ZeRO-style) when both divide -- a 1T-param MoE (Kimi K2)
  only fits HBM with this 256-way expert-weight sharding;
* tokens are routed top-k with a per-group capacity ``C = G*k/E * cf``;
  dispatch/combine are einsums against a one-hot (G, E, C) tensor, which
  GSPMD turns into the all-to-all between the ``data`` (token) and ``model``
  (expert) axes -- the collective the roofline analysis attributes to MoE;
* tokens are processed in groups (sequence chunks) so the dispatch one-hot
  stays small; groups are a vmapped leading dim.

Router load-balance: the standard aux loss (mean gate fraction * mean router
prob per expert, scaled by E) is returned for the trainer to add.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import sharding
from .config import ArchConfig
from .layers import dtype_of


def _expert_ff_axis(cfg: ArchConfig) -> Tuple:
    """(expert_axis_spec, ff_axis_spec) for (E, d, ff) expert weights."""
    e = cfg.n_experts
    model = sharding.axis_size("model")
    data = sharding.axis_size("data")
    ff = cfg.moe_d_ff or cfg.d_ff
    e_ax = "model" if (model > 1 and e % model == 0) else None
    ff_ax = "data" if (data > 1 and ff % data == 0) else None
    return e_ax, ff_ax


def init_moe(key: jax.Array, cfg: ArchConfig):
    d, e = cfg.d_model, cfg.n_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 7)
    dt = dtype_of(cfg)
    params = {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, ff)) * d ** -0.5).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, ff)) * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, ff, d)) * ff ** -0.5).astype(dt),
    }
    e_ax, ff_ax = _expert_ff_axis(cfg)
    specs = {
        "router": P(None, None),
        "w_gate": P(e_ax, None, ff_ax),
        "w_up": P(e_ax, None, ff_ax),
        "w_down": P(e_ax, ff_ax, None),
    }
    if cfg.shared_experts:
        se_ff = ff * cfg.shared_experts
        params.update({
            "sh_gate": (jax.random.normal(ks[4], (d, se_ff)) * d ** -0.5).astype(dt),
            "sh_up": (jax.random.normal(ks[5], (d, se_ff)) * d ** -0.5).astype(dt),
            "sh_down": (jax.random.normal(ks[6], (se_ff, d)) * se_ff ** -0.5).astype(dt),
        })
        specs.update({"sh_gate": P(None, "model"), "sh_up": P(None, "model"),
                      "sh_down": P("model", None)})
    return params, specs


# Below this group size the dispatch tensor is cheap enough to give every
# token a guaranteed slot (capacity == group): no token is ever dropped.
# Dropless routing is what makes single-token decode consistent with the
# teacher-forced forward pass -- with finite capacity, a token's expert
# assignment depends on which *other* tokens share its group, so decode
# (groups of B tokens) and prefill (groups of B*S) drop differently.
# Scope: decode groups (the serving batch) are essentially always under the
# threshold, so *decode is always dropless*; the decode==forward guarantee
# therefore holds when the teacher-forced pass also stays within one
# dropless group (B*S <= 256, the smoke/consistency-test regime).  Larger
# training prefills keep GShard capacity on purpose -- a 1024-token group
# with capacity==group would make the (G, E, C) dispatch tensor quadratic
# in G, and training-time drops are a standard throughput tradeoff.
DROPLESS_MAX_GROUP = 256


def _capacity(cfg: ArchConfig, group: int) -> int:
    if group <= DROPLESS_MAX_GROUP:
        return group
    c = int(group * cfg.top_k / cfg.n_experts * cfg.capacity_factor) + 1
    return max(c, cfg.top_k)


def moe_ffn(cfg: ArchConfig, params, x: jax.Array, *, group: int = 1024):
    """MoE FFN.  x: (B, S, d) -> (y, aux_loss).

    Tokens are reshaped into (n_groups, G, d); dispatch runs per group.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = min(group, b * s)
    n_tok = b * s
    # pad token count to a multiple of the group size
    n_groups = -(-n_tok // g)
    xt = x.reshape(n_tok, d)
    pad = n_groups * g - n_tok
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = xt.reshape(n_groups, g, d)
    xg = sharding.constraint(xg, P(sharding.batch_axes(), None, None))

    logits = (xg.astype(jnp.float32) @ params["router"])          # (n, G, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                 # (n, G, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- capacity assignment --------------------------------------------
    c = _capacity(cfg, g)
    dt = x.dtype
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)       # (n, G, k, E)
    # position of each (token, slot) within its expert queue (f32 exact
    # for counts up to 2^24; the dispatch/combine tensors themselves are
    # cast to the model dtype so no f32 leaks into the xe collectives --
    # §Perf kimi iteration 5)
    pos = jnp.cumsum(onehot.reshape(n_groups, g * k, e), axis=1).reshape(
        n_groups, g, k, e) - 1.0
    keep = (pos < c) & (onehot > 0)
    pos = jnp.sum(pos * onehot, axis=-1)                          # (n, G, k)
    cap_onehot = jax.nn.one_hot(pos.astype(jnp.int32), c, dtype=dt)
    kept = (onehot * keep).astype(dt)
    # dispatch tensor (n, G, E, C)
    dispatch = jnp.einsum("ngke,ngkc->ngec", kept, cap_onehot,
                          preferred_element_type=dt)
    combine = jnp.einsum("ngk,ngke,ngkc->ngec",
                         gate_vals.astype(dt), kept, cap_onehot,
                         preferred_element_type=dt)

    # Sharding note (EXPERIMENTS.md §Perf, kimi iteration 1 -- refuted
    # hypothesis): keeping the group dim on `data` through the expert
    # compute forces ZeRO-sharded expert weights to be all-gathered every
    # microbatch (8.5e12 B/dev vs 3.8e12 baseline).  Replicating the group
    # dim lets GSPMD gather token-proportional activations instead, which
    # is cheaper for a 1T-param MoE where weights >> activations.
    # bf16 partial-sum accumulation (preferred_element_type) halves the
    # cross-device reductions of the dispatch/expert einsums (iteration 2).
    xe = jnp.einsum("ngec,ngd->necd", dispatch, xg,
                    preferred_element_type=dt)                    # (n, E, C, d)
    e_ax, ff_ax = _expert_ff_axis(cfg)
    xe = sharding.constraint(xe, P(None, e_ax, None, None))

    hidden = jax.nn.silu(
        jnp.einsum("necd,edf->necf", xe, params["w_gate"],
                   preferred_element_type=dt)) \
        * jnp.einsum("necd,edf->necf", xe, params["w_up"],
                     preferred_element_type=dt)
    hidden = sharding.constraint(hidden, P(None, e_ax, None, ff_ax))
    ye = jnp.einsum("necf,efd->necd", hidden, params["w_down"],
                    preferred_element_type=dt)
    ye = sharding.constraint(ye, P(None, e_ax, None, None))

    y = jnp.einsum("ngec,necd->ngd", combine, ye,
                   preferred_element_type=dt)                     # (n, G, d)
    y = y.reshape(n_groups * g, d)[:n_tok].reshape(b, s, d)
    y = sharding.constraint(y, P(sharding.batch_axes(), None, None))

    if cfg.shared_experts:
        sh = jax.nn.silu(x @ params["sh_gate"]) * (x @ params["sh_up"])
        sh = sharding.constraint(sh, P(sharding.batch_axes(), None, "model"))
        y = y + sh @ params["sh_down"]

    # ---- load-balance aux loss (Switch/GShard) ---------------------------
    frac_tokens = jnp.mean(onehot[..., 0, :], axis=(0, 1))        # top-1 fraction
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return y, aux
