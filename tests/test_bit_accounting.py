"""Property-based bit-accounting suite over the whole channel matrix.

DoCoFL-style bi-directional compression papers live and die by exact bit
bookkeeping per direction, so the accounting invariants are pinned here for
**every** channel in ``registry.all_schemes`` (static and adaptive):

* bits are non-negative, finite Python floats under a host (static) plan;
* the functional core and the object shell report identical bits
  (``step_up``/``step_down`` vs ``transmit``/``distribute``);
* bits are additive across rounds, and ``BitMeter.book_run`` records
  exactly what the per-round channel reports sum to (== an ``add_round``
  loop, including per-round overhead sequences);
* bits are invariant to cohort permutation (and, for cohort-sized
  formulas, to *which* equally-sized cohort participates);
* a traced bucketed plan (``finalize_plan``) yields the same bits value as
  the host plan with the same billable block count -- the traced-bits
  contract degrades representation, never value.
"""
import math

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bernoulli import bern_kl, clip01
from repro.core.bitmeter import BitMeter
from repro.core.blocks import AdaptiveAllocation
from repro.fl import registry
from repro.fl.channels import BlockPlan, RoundContext

N, D = 3, 96
SCHEMES = registry.all_schemes(n=N, d=D, n_is=8, block=32, reset_period=2,
                               include_adaptive=True)
SCHEME_IDS = [s[0] for s in SCHEMES]


def _round_inputs(kind: str, key: int = 0):
    rng = np.random.default_rng(key)
    if kind == "mask":
        payload = jnp.asarray(rng.uniform(0.05, 0.95, (N, D)), jnp.float32)
        priors = jnp.asarray(rng.uniform(0.05, 0.95, (N, D)), jnp.float32)
        theta = jnp.asarray(rng.uniform(0.05, 0.95, D), jnp.float32)
    else:
        payload = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
        priors = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
        theta = jnp.asarray(rng.standard_normal(D), jnp.float32)
    return payload, priors, theta


def _host_plan(spec, payload, priors):
    if spec.allocation is None:
        return None
    kl = None
    if getattr(spec.allocation, "needs_kl", True):
        kl = np.asarray(jnp.mean(jax.vmap(bern_kl)(payload, clip01(priors)),
                                 axis=0))
    size, n_blocks, seg_ids, overhead = spec.allocation.plan(kl, D)
    return BlockPlan(size=size, n_blocks=n_blocks, seg_ids=seg_ids,
                     overhead_bits=overhead)


def _ctx(spec, payload, priors, active=None):
    plan = _host_plan(spec, payload, priors)
    active = np.arange(N) if active is None else np.asarray(active)
    return RoundContext(t=0, key=jax.random.PRNGKey(7), n_clients=N, d=D,
                        active=active, plan=plan)


def _one_round(spec, ctx, payload, priors, theta):
    """Functional-core round; returns (ul_bits, dl_bits, shell bits pair)."""
    up_s = spec.uplink.init_up_state(N, D)
    up_out, ul_bits, _ = spec.uplink.step_up(ctx, up_s, payload, priors)
    update = spec.aggregator(ctx, theta, up_out)
    theta_hat = jnp.tile(theta[None], (N, 1))
    dn_s = spec.downlink.init_down_state(N, D)
    res, _ = spec.downlink.step_down(ctx, dn_s, update, theta, theta_hat)

    # object shell must report the identical bits
    for chan in (spec.uplink, spec.downlink):
        reset = getattr(chan, "reset", None)
        if reset is not None:
            reset()
    _, ul_shell = spec.uplink.transmit(ctx, payload, priors)
    res_shell = spec.downlink.distribute(ctx, update, theta, theta_hat)
    return ul_bits, res.bits, ul_shell, res_shell.bits


@pytest.mark.parametrize("name,kind,factory", SCHEMES, ids=SCHEME_IDS)
def test_bits_nonneg_finite_static_float(name, kind, factory):
    spec = factory()
    payload, priors, theta = _round_inputs(kind)
    ctx = _ctx(spec, payload, priors)
    ul, dl, ul_shell, dl_shell = _one_round(spec, ctx, payload, priors, theta)
    for b in (ul, dl):
        # host-plan contract: a plain, data-independent Python number
        assert isinstance(b, (int, float)), (name, type(b))
        assert math.isfinite(b) and b >= 0.0, (name, b)
    assert ul_shell == ul and dl_shell == dl, name
    if ctx.plan is not None:
        oh = float(ctx.plan.overhead_bits)
        assert math.isfinite(oh) and oh >= 0.0


@pytest.mark.parametrize("name,kind,factory", SCHEMES, ids=SCHEME_IDS)
def test_bits_invariant_to_cohort_permutation(name, kind, factory):
    spec = factory()
    payload, priors, theta = _round_inputs(kind)
    ul0, dl0, *_ = _one_round(spec, _ctx(spec, payload, priors),
                              payload, priors, theta)
    perm = np.array([2, 0, 1])
    ul1, dl1, *_ = _one_round(spec, _ctx(spec, payload, priors, active=perm),
                              payload[perm], priors[perm], theta)
    assert ul1 == ul0 and dl1 == dl0, name


@pytest.mark.parametrize("name,kind,factory", SCHEMES, ids=SCHEME_IDS)
def test_bits_additive_and_book_run_matches_steps(name, kind, factory):
    """Booking the per-round channel reports through BitMeter.book_run must
    equal an add_round loop and the plain sums -- for every scheme."""
    spec = factory()
    rounds = []
    for r in range(3):
        payload, priors, theta = _round_inputs(kind, key=r)
        ctx = _ctx(spec, payload, priors)
        ul, dl, *_ = _one_round(spec, ctx, payload, priors, theta)
        oh = float(ctx.plan.overhead_bits) * N if ctx.plan is not None else 0.0
        rounds.append((ul, dl, oh))
    uls, dls, ohs = map(list, zip(*rounds))

    bulk = BitMeter(n_clients=N, d=D)
    snaps = bulk.book_run(uls, dls, overhead_bits=ohs)
    loop = BitMeter(n_clients=N, d=D)
    for u, dl_, oh in rounds:
        loop.add_round(u, dl_, overhead_bits=oh)
    assert bulk.summary() == loop.summary(), name
    assert bulk.total_bits == sum(uls) + sum(dls) + sum(ohs), name
    assert bulk.uplink_bits == sum(uls) + sum(ohs), name
    assert bulk.downlink_bits == sum(dls), name
    # per-round history mirrors what was booked, cumulatively
    assert [h["cum_bits"] for h in bulk.history] == [s[0] for s in snaps]
    assert bulk.rounds == 3


def test_flush_bits_nonneg_finite():
    """EF flush bills a dense sync; the report must be a finite float."""
    for name, kind, factory in SCHEMES:
        spec = factory()
        if not spec.sync_period:
            continue
        for chan, state in ((spec.uplink, spec.uplink.init_up_state(N, D)),
                            (spec.downlink,
                             spec.downlink.init_down_state(N, D))):
            _, bits, _ = chan.flush_step(state, N, D)
            assert isinstance(bits, (int, float))
            assert math.isfinite(bits) and bits >= 0.0, name


def test_traced_bucketed_bits_equal_host_bits():
    """A finalize_plan-built (traced) plan with the same billable count must
    produce the same bits *value* as the host plan -- only the
    representation (jnp scalar vs Python float) may differ."""
    spec = registry.bicompfl_spec("GR", allocation=AdaptiveAllocation(n_is=8),
                                  n_is=8, n_dl=N)
    payload, priors, theta = _round_inputs("mask")
    ctx = _ctx(spec, payload, priors)
    host_plan = ctx.plan
    alloc = spec.allocation
    klp = jnp.mean(jax.vmap(bern_kl)(payload, clip01(priors)), axis=0)
    stats = {"profile": klp, "total": jnp.sum(klp)}
    tmpl = BlockPlan(size=None, n_blocks=host_plan.n_blocks, seg_ids=None,
                     overhead_bits=0.0)
    traced_plan = alloc.finalize_plan(tmpl, stats, D)
    assert int(traced_plan.billable) == host_plan.billable

    ctx_traced = RoundContext(t=0, key=jax.random.PRNGKey(7), n_clients=N,
                              d=D, active=np.arange(N), plan=traced_plan)
    _, bits_host, _ = spec.uplink.step_up(
        ctx, spec.uplink.init_up_state(N, D), payload, priors)
    _, bits_traced, _ = spec.uplink.step_up(
        ctx_traced, spec.uplink.init_up_state(N, D), payload, priors)
    assert isinstance(bits_host, float)
    assert isinstance(bits_traced, jnp.ndarray)  # the traced representation
    assert float(bits_traced) == bits_host
    assert float(traced_plan.overhead_bits) == float(host_plan.overhead_bits)


def test_fused_traced_bits_overflow_guard():
    """Traced per-round bits above the f32 integer-exact bound (2**24) must
    raise loudly instead of booking silently-rounded totals."""
    import jax as _jax
    from repro.fl.channels import IndexRelayDownlink
    from repro.fl.data import make_synthetic, partition_iid
    from repro.fl.engine import FLEngine
    from repro.fl.nets import make_mlp
    from repro.fl.tasks import make_mask_task

    k = _jax.random.PRNGKey(0)
    train, test = make_synthetic(k, n_train=60, n_test=30, hw=4, noise=0.5)
    shards = partition_iid(_jax.random.fold_in(k, 1), train, 3, 20)
    net = make_mlp(in_dim=16, widths=(8,), signed_constant=True)
    task = make_mask_task(net, _jax.random.fold_in(k, 2), test.x, test.y,
                          local_epochs=1, batch_size=20)
    spec = registry.bicompfl_spec("GR", allocation=AdaptiveAllocation(n_is=8),
                                  n_is=8, n_dl=3)
    spec.downlink = IndexRelayDownlink(n_is=8, side_info_bits=2.0 ** 25)
    with pytest.raises(OverflowError):
        FLEngine(task, spec).run(shards, rounds=1, seed=0, mode="fused")


class TestBitMeterProperties:
    @settings(max_examples=8)
    @given(st.floats(min_value=0.0, max_value=1e9),
           st.floats(min_value=0.0, max_value=1e9),
           st.floats(min_value=0.0, max_value=1e6),
           st.integers(min_value=1, max_value=12))
    def test_book_run_additivity(self, ul, dl, oh, rounds):
        m = BitMeter(n_clients=N, d=D)
        m.book_run([ul] * rounds, [dl] * rounds, overhead_bits=oh)
        assert m.rounds == rounds
        np.testing.assert_allclose(m.total_bits, (ul + dl + oh) * rounds,
                                   rtol=1e-12)
        np.testing.assert_allclose(
            m.total_bpp, m.total_bits / (N * D * rounds), rtol=1e-12)

    @settings(max_examples=8)
    @given(st.integers(min_value=1, max_value=10))
    def test_book_run_order_independent_totals(self, rounds):
        """Totals are permutation-invariant in the round order (additivity:
        the meter is a running sum, not an order-sensitive statistic)."""
        rng = np.random.default_rng(rounds)
        uls = list(rng.uniform(0, 1e6, rounds))
        dls = list(rng.uniform(0, 1e6, rounds))
        a = BitMeter(n_clients=N, d=D)
        a.book_run(uls, dls)
        b = BitMeter(n_clients=N, d=D)
        b.book_run(uls[::-1], dls[::-1])
        np.testing.assert_allclose(a.total_bits, b.total_bits, rtol=1e-12)
        assert a.rounds == b.rounds
