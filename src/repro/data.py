"""Synthetic token pipeline for LM training (offline container).

A deterministic, seedable stream of (tokens, labels) batches with a
controllable Markov structure so the LM loss actually decreases -- pure
random tokens would have no learnable signal.  The generator is
host-side numpy (as a real input pipeline would be) with an async-style
``prefetch`` iterator.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ArchConfig


class TokenPipeline:
    """Order-1 Markov token stream over an effective alphabet.

    ``alpha`` controls predictability: each row of the transition matrix is
    a Dirichlet(alpha) draw -- small alpha => peaked rows => low entropy.
    """

    def __init__(self, vocab: int, *, seed: int = 0, effective_vocab: int = 256,
                 alpha: float = 0.01):
        self.vocab = vocab
        self.eff = min(effective_vocab, vocab)
        rng = np.random.default_rng(seed)
        self.trans = rng.dirichlet(np.full(self.eff, alpha), size=self.eff)
        self.cum = np.cumsum(self.trans, axis=1)
        # map effective ids onto the full vocab (spread out)
        self.id_map = (np.arange(self.eff) * max(vocab // self.eff, 1)) % vocab
        self.rng = rng

    def batch(self, batch: int, seq: int) -> Dict[str, np.ndarray]:
        u = self.rng.random((batch, seq))
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, 0] = self.rng.integers(0, self.eff, batch)
        for t in range(seq):
            toks[:, t + 1] = (
                self.cum[toks[:, t]] < u[:, t][:, None]).sum(axis=1)
        mapped = self.id_map[toks]
        return {"tokens": mapped[:, :-1].astype(np.int32),
                "labels": mapped[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch(self._batch, self._seq)

    def stream(self, batch: int, seq: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch(batch, seq)


def batches_for(cfg: ArchConfig, batch: int, seq: int, *, seed: int = 0,
                n: Optional[int] = None):
    """Batch iterator with the modality extras each arch needs."""
    pipe = TokenPipeline(cfg.vocab, seed=seed)
    rng = np.random.default_rng(seed + 1)
    it = pipe.stream(batch, seq)
    count = 0
    for b in it:
        if not cfg.embed_inputs:  # audio: frame embeddings replace tokens
            b = {"inputs": rng.standard_normal(
                (batch, seq, cfg.d_model)).astype(np.float32) * 0.02,
                "labels": b["labels"] % cfg.vocab}
        elif cfg.vlm_image_tokens:
            b = dict(b)
            b["image_embeds"] = rng.standard_normal(
                (batch, cfg.vlm_image_tokens, cfg.d_model)).astype(np.float32) * 0.02
            if cfg.rope_kind == "mrope":
                pos = np.broadcast_to(np.arange(seq)[None, :, None],
                                      (batch, seq, 3)).astype(np.int32)
                b["positions"] = np.ascontiguousarray(pos)
        yield b
        count += 1
        if n is not None and count >= n:
            return
