"""Roofline analyzer: HLO collective parsing + term arithmetic."""
import numpy as np

from repro.launch.roofline import (Roofline, _shape_bytes, parse_collectives)
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

HLO = """
HloModule jit_step

ENTRY %main (p0: bf16[16,4096,7168]) -> bf16[16,4096,7168] {
  %p0 = bf16[16,4096,7168]{2,1,0} parameter(0)
  %all-gather.1 = bf16[16,4096,7168]{2,1,0} all-gather(%p0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(%c), to_apply=%add
  %rs.2 = f32[64,128]{1,0} reduce-scatter(%ar2), dimensions={0}
  %a2a = (f32[8,16]{1,0}, f32[8,16]{1,0}) all-to-all(%x, %y), dimensions={0}
  %cp = u32[4]{0} collective-permute(%idx), source_target_pairs={{0,1}}
  ROOT %out = bf16[16,4096,7168]{2,1,0} copy(%all-gather.1)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,4096,7168]") == 16 * 4096 * 7168 * 2
    assert _shape_bytes("f32[]") == 4
    assert _shape_bytes("(f32[8,16], f32[8,16])") == 2 * 8 * 16 * 4


def test_parse_collectives_kinds_and_bytes():
    st = parse_collectives(HLO)
    assert st.count_by_kind == {"all-gather": 1, "all-reduce": 1,
                                "reduce-scatter": 1, "all-to-all": 1,
                                "collective-permute": 1}
    assert st.bytes_by_kind["all-gather"] == 16 * 4096 * 7168 * 2
    assert st.bytes_by_kind["all-reduce"] == 1024 * 4
    assert st.bytes_by_kind["all-to-all"] == 2 * 8 * 16 * 4
    assert st.bytes_by_kind["collective-permute"] == 16


def test_parse_ignores_non_collectives():
    st = parse_collectives("%x = f32[8]{0} add(%a, %b)\n")
    assert st.total_bytes == 0 and st.total_count == 0


def test_roofline_terms():
    rl = Roofline(flops=PEAK_FLOPS_BF16, hbm_bytes=HBM_BW / 2,
                  collective_bytes=ICI_BW_PER_LINK / 4, chips=256)
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.memory_s - 0.5) < 1e-9
    assert abs(rl.collective_s - 0.25) < 1e-9
    assert rl.dominant == "compute"
    assert abs(rl.step_time_s - 1.0) < 1e-9


def test_dominant_switches():
    rl = Roofline(flops=0.0, hbm_bytes=0.0, collective_bytes=ICI_BW_PER_LINK,
                  chips=1)
    assert rl.dominant == "collective"
