"""Scheme registry: config -> (uplink, downlink, aggregator) factories.

Every named FL scheme in the repo is a factory returning an
:class:`~repro.fl.engine.EngineSpec`.  The old string-dispatch if/else
chains in ``run_bicompfl`` / ``run_baseline`` are gone; adding a scheme is
one entry here.  New combinations that no seed loop could express -- e.g.
an MRC uplink with a sign-EF downlink -- are just a hand-rolled EngineSpec
from the same channel parts (see tests/test_channels.py).
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.core.blocks import (AdaptiveAllocation, AdaptiveAvgAllocation,
                               FixedAllocation)
from repro.core.quantizers import FLOAT_BITS
from repro.kernels.ops import mrc_logw_fn, segment_logw_fn
from .channels import (DenseChannel, IndexRelayDownlink, MRCAdaptiveChannel,
                       MRCBroadcastDownlink, MRCFixedChannel,
                       MRCPrivateDownlink, QuantizedMRCUplink, SignEFChannel,
                       SliceDownlink, SplitBlockDownlink, TopKEFChannel)
from .engine import EngineSpec, MeanDeltaAggregator, MeanModelAggregator

BICOMPFL_VARIANTS = ("GR", "GR-Reconst", "PR", "PR-SplitDL")


def bicompfl_spec(variant: str, *, allocation, n_is: int = 256, n_ul: int = 1,
                  n_dl: int = 1, chunk: int = 16, logw_fn=None,
                  participation: float = 1.0,
                  pallas_logw: bool = False,
                  segment_logw_pallas: bool = False) -> EngineSpec:
    """BiCompFL (probabilistic-mask) variants, paper Algorithms 1 & 2.

    ``n_dl`` must be resolved by the caller (the paper default is
    ``n_clients * n_ul``, which needs the cohort size).  ``pallas_logw``
    routes the fixed-block MRC importance-weight matvec through the Pallas
    ``mrc_weights`` kernel (``repro.kernels.ops.mrc_logw_fn``) on both
    directions; ``segment_logw_pallas`` is the adaptive-segment analog,
    routing the variable-block weight evaluation through the Pallas
    segment-logW kernel (``repro.kernels.ops.segment_logw_fn``) wherever a
    channel encodes against an adaptive plan.
    """
    if variant not in BICOMPFL_VARIANTS:
        raise ValueError(variant)
    if pallas_logw:
        if logw_fn is not None:
            raise ValueError("pass either logw_fn or pallas_logw, not both")
        logw_fn = mrc_logw_fn()
    seg_logw_fn = segment_logw_fn() if segment_logw_pallas else None
    if participation < 1.0 and variant != "PR":
        raise ValueError("partial participation requires private shared "
                         "randomness (the PR variant); GR needs all clients "
                         "to track the common candidate stream, and SplitDL "
                         "partitions the downlink across the full cohort")
    shared = variant.startswith("GR")
    adaptive = isinstance(allocation, AdaptiveAllocation)
    if adaptive:
        uplink = MRCAdaptiveChannel(n_is=n_is, n_samples=n_ul, shared=shared,
                                    seg_logw_fn=seg_logw_fn)
    else:
        uplink = MRCFixedChannel(n_is=n_is, n_samples=n_ul, shared=shared,
                                 chunk=chunk, logw_fn=logw_fn)
    if variant == "GR":
        downlink = IndexRelayDownlink(n_is=n_is, n_samples=n_ul)
    elif variant == "GR-Reconst":
        downlink = MRCBroadcastDownlink(n_is=n_is, n_samples=n_dl,
                                        chunk=chunk, logw_fn=logw_fn,
                                        seg_logw_fn=seg_logw_fn)
    elif variant == "PR":
        downlink = MRCPrivateDownlink(n_is=n_is, n_samples=n_dl,
                                      chunk=chunk, logw_fn=logw_fn,
                                      seg_logw_fn=seg_logw_fn)
    else:  # PR-SplitDL
        if adaptive:
            raise NotImplementedError("SplitDL is defined on fixed blocks")
        downlink = SplitBlockDownlink(n_is=n_is, n_samples=n_dl,
                                      chunk=chunk, logw_fn=logw_fn)
    return EngineSpec(uplink=uplink, downlink=downlink,
                      aggregator=MeanModelAggregator(), allocation=allocation,
                      participation=participation,
                      name=f"BiCompFL-{variant}")


def cfl_spec(*, n_is: int = 256, n_ul: int = 1, block_size: int = 16,
             server_lr: float = 1.0, chunk: int = 16, logw_fn=None) -> EngineSpec:
    """BiCompFL-GR-CFL: stochastic sign + MRC in conventional FL (Sec. 4)."""
    return EngineSpec(
        uplink=QuantizedMRCUplink(n_is=n_is, n_samples=n_ul, chunk=chunk,
                                  logw_fn=logw_fn),
        downlink=IndexRelayDownlink(n_is=n_is, n_samples=n_ul,
                                    side_info_bits=FLOAT_BITS),
        aggregator=MeanDeltaAggregator(server_lr),
        allocation=FixedAllocation(block_size),
        name="BiCompFL-GR-CFL")


# ---------------------------------------------------------------------------
# Non-stochastic baselines (paper Section 4); simplifications cf. DESIGN.md.
# ---------------------------------------------------------------------------


def _fedavg(n, d, lr, period):
    return EngineSpec(DenseChannel(), DenseChannel(), MeanDeltaAggregator(lr),
                      name="fedavg")


def _memsgd(n, d, lr, period):
    return EngineSpec(SignEFChannel(), DenseChannel(), MeanDeltaAggregator(lr),
                      name="memsgd")


def _doublesqueeze(n, d, lr, period):
    return EngineSpec(SignEFChannel(), SignEFChannel(), MeanDeltaAggregator(lr),
                      name="doublesqueeze")


def _neolithic(n, d, lr, period):
    return EngineSpec(SignEFChannel(passes=2), SignEFChannel(passes=2),
                      MeanDeltaAggregator(lr), name="neolithic")


def _cser(n, d, lr, period):
    return EngineSpec(SignEFChannel(), DenseChannel(), MeanDeltaAggregator(lr),
                      sync_period=period, name="cser")


def _liec(n, d, lr, period):
    return EngineSpec(SignEFChannel(), SignEFChannel(), MeanDeltaAggregator(lr),
                      sync_period=period, name="liec")


def _m3(n, d, lr, period):
    k = max(d // n, 1)  # one budget shared by the top-k uplink and the slices
    return EngineSpec(TopKEFChannel(k=k), SliceDownlink(k=k),
                      MeanDeltaAggregator(lr), name="m3")


BASELINE_BUILDERS: Dict[str, Callable[[int, int, float, int], EngineSpec]] = {
    "fedavg": _fedavg,
    "memsgd": _memsgd,
    "doublesqueeze": _doublesqueeze,
    "neolithic": _neolithic,
    "cser": _cser,
    "liec": _liec,
    "m3": _m3,
}

ALL_BASELINES = tuple(BASELINE_BUILDERS)


def baseline_spec(scheme: str, *, n: int, d: int, server_lr: float = 1.0,
                  reset_period: int = 50) -> EngineSpec:
    """Build a baseline EngineSpec; needs cohort size and model dimension
    (M3's top-k budget is d/n)."""
    key = scheme.lower()
    if key not in BASELINE_BUILDERS:
        raise ValueError(scheme)
    return BASELINE_BUILDERS[key](n, d, server_lr, reset_period)


def all_schemes(*, n: int, d: int, n_is: int = 16, block: int = 64,
                n_dl: int = None, server_lr: float = 1.0,
                reset_period: int = 50, include_adaptive: bool = False):
    """Every named scheme as ``(name, task_kind, spec_factory)`` triples.

    ``task_kind`` is "mask" (probabilistic-mask BiCompFL) or "delta"
    (conventional-FL: the baselines and BiCompFL-CFL).  Factories build a
    fresh spec per call -- EF channels carry state, so parity sweeps must
    never share channel instances between runs.  Used by the fused-vs-host
    parity suite, the bit-accounting property suite and the
    round-throughput benchmark to enumerate the scheme matrix.

    ``include_adaptive=True`` appends the KL-driven allocations (the
    Isik-style segment codec on GR and PR, plus the paper's low-complexity
    Adaptive-Avg).  They are kept out of the default matrix because the
    fused engine runs them through *bucketed* plans -- equal to the host
    loop's exact plan only up to the bucketing bound, where the static
    schemes are bit-identical across engine paths.
    """
    ndl = n if n_dl is None else n_dl
    out = []
    for v in BICOMPFL_VARIANTS:
        out.append((f"bicompfl-{v.lower()}", "mask",
                    lambda v=v: bicompfl_spec(
                        v, allocation=FixedAllocation(block), n_is=n_is,
                        n_dl=ndl)))
    if include_adaptive:
        out.append(("bicompfl-gr-adaptive", "mask",
                    lambda: bicompfl_spec(
                        "GR", allocation=AdaptiveAllocation(n_is=n_is),
                        n_is=n_is, n_dl=ndl)))
        out.append(("bicompfl-pr-adaptive", "mask",
                    lambda: bicompfl_spec(
                        "PR", allocation=AdaptiveAllocation(n_is=n_is),
                        n_is=n_is, n_dl=ndl)))
        out.append(("bicompfl-gr-adaptive-avg", "mask",
                    lambda: bicompfl_spec(
                        "GR",
                        allocation=AdaptiveAvgAllocation(
                            n_is=n_is, min_block=block // 2,
                            max_block=8 * block),
                        n_is=n_is, n_dl=ndl)))
    out.append(("bicompfl-cfl", "delta",
                lambda: cfl_spec(n_is=n_is, block_size=16,
                                 server_lr=server_lr)))
    for s in ALL_BASELINES:
        out.append((s, "delta",
                    lambda s=s: baseline_spec(s, n=n, d=d,
                                              server_lr=server_lr,
                                              reset_period=reset_period)))
    return out


def wire_scheme_ids(*, n: int = 4, d: int = 64) -> Dict[str, int]:
    """Frame-header scheme ids for the full registry matrix.

    The engine stamps ``scheme_wire_id(spec.name)`` into every message of
    a wire-audited run; this enumerates the id of each registry scheme and
    fails loudly if two distinct spec names ever hash to the same 16-bit
    id (tests/test_wire.py pins the absence of collisions).
    """
    from repro.wire import scheme_wire_id
    ids: Dict[str, int] = {}
    by_id: Dict[int, str] = {}
    for _, _, factory in all_schemes(n=n, d=d, include_adaptive=True):
        name = factory().name
        wid = scheme_wire_id(name)
        if by_id.get(wid, name) != name:
            raise ValueError(
                f"wire scheme-id collision: {name!r} and {by_id[wid]!r} "
                f"both hash to {wid:#06x}")
        by_id[wid] = name
        ids[name] = wid
    return ids


def fault_matrix(*, n: int, d: int, n_is: int = 16, block: int = 64,
                 n_dl: int = None, reset_period: int = 2):
    """One scheme per uplink channel family, for fault-injection sweeps.

    The fault machinery's degradation paths split by channel *family*
    (MRC index streams, quantized-MRC deltas, sign-EF, top-k EF, dense),
    not by scheme, so the CI fault matrix and the robustness tests cover
    each family once instead of re-running the full registry:

    * ``bicompfl-pr``  -- MRC fixed-block uplink + client-specific
      (``downlink_recipients="active"``) MRC private downlink;
    * ``bicompfl-cfl`` -- quantized-MRC delta uplink, broadcast downlink;
    * ``doublesqueeze`` -- sign compression with error feedback on both
      links (EF rows must be carried for dropped clients);
    * ``m3``           -- top-k EF uplink (index payloads of varying
      width; excluded from uniform per-client wire-bit assertions);
    * ``fedavg``       -- dense float uplink, the no-compression control.

    Same ``(name, task_kind, factory)`` triples as :func:`all_schemes`.
    """
    ndl = n if n_dl is None else n_dl
    return [
        ("bicompfl-pr", "mask",
         lambda: bicompfl_spec("PR", allocation=FixedAllocation(block),
                               n_is=n_is, n_dl=ndl)),
        ("bicompfl-cfl", "delta",
         lambda: cfl_spec(n_is=n_is, block_size=16)),
        ("doublesqueeze", "delta",
         lambda: baseline_spec("doublesqueeze", n=n, d=d,
                               reset_period=reset_period)),
        ("m3", "delta",
         lambda: baseline_spec("m3", n=n, d=d, reset_period=reset_period)),
        ("fedavg", "delta",
         lambda: baseline_spec("fedavg", n=n, d=d,
                               reset_period=reset_period)),
    ]
