"""Message framing: the self-describing envelope around channel payloads.

Every encoded channel payload travels inside one :class:`Message` frame:

====================  =====  ====================================
field                 bits   meaning
====================  =====  ====================================
magic                 16     ``MAGIC`` (0xB1C0)
version               8      ``VERSION`` (bump on layout change)
round                 32     global round index t
direction             8      DIR_* (uplink / downlink / control /
                             flush-up / flush-down)
scheme_id             16     crc32(scheme name) & 0xFFFF
sender                16     client id, or ``SERVER``
recipient             16     client id, or ``SERVER``
payload_bits          32     exact payload length in bits
====================  =====  ====================================

Header total: ``FRAME_HEADER_BITS`` = 144 (18 bytes, byte-aligned by
construction).  The payload follows immediately and is zero-padded to the
next byte boundary (< 8 pad bits per message); a ``FRAME_TRAILER_BITS`` =
32-bit CRC32 over the frame's header + payload + pad bytes closes the
frame (format v2), so frames concatenate into one byte stream that
:meth:`WireSession.parse` can split back apart *and* every frame carries
its own integrity check.  CRC32 detects every single-bit flip and every
burst error up to 32 bits; a mismatch raises
:class:`~repro.wire.bitio.WireIntegrityError`, truncation or garbage
raises :class:`~repro.wire.bitio.WireFormatError` -- both are
:class:`~repro.wire.bitio.WireError`, never a bare ``IndexError``.

The **reconcile tolerance contract** (see DESIGN.md): booked BitMeter
bits and summed payload bits must agree to within ``RECONCILE_TOL_BITS``
(= 0.0 -- codecs are exact) plus a 1e-9 *relative* slack for float64
bookkeeping round-off (e.g. ``SliceDownlink`` books ``n * (d/n) * 32``,
whose float division may differ from the integer stream length by ULPs).
Framing overhead is audited separately: it must lie in
``[n_messages * FRAME_OVERHEAD_BITS,
n_messages * (FRAME_OVERHEAD_BITS + 7)]`` where ``FRAME_OVERHEAD_BITS``
= header + CRC trailer.  Retransmitted (corrupted-in-flight) frames are
tracked on the session as *wasted* copies: their payload bits reconcile
against the meter's ``retransmit_bits`` category, never against the
clean per-direction totals.  Widening any bound is a format change and
must be reflected in DESIGN.md (tests/test_wire.py tripwires the
documented values).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from .bitio import (BitReader, BitWriter, WireError, WireFormatError,
                    WireIntegrityError)

MAGIC = 0xB1C0
VERSION = 2   # v2: CRC32 trailer after the padded payload

DIR_UP = 0          # client -> server channel payload
DIR_DOWN = 1        # server -> client channel payload
DIR_CTRL = 2        # server -> client block-plan header (allocation overhead)
DIR_FLUSH_UP = 3    # client -> server EF-memory sync payload
DIR_FLUSH_DOWN = 4  # server -> client synced-model broadcast
_DIRECTIONS = (DIR_UP, DIR_DOWN, DIR_CTRL, DIR_FLUSH_UP, DIR_FLUSH_DOWN)

# Directions whose payload bits the BitMeter books on each link.
UPLINK_DIRS = frozenset({DIR_UP, DIR_CTRL, DIR_FLUSH_UP})
DOWNLINK_DIRS = frozenset({DIR_DOWN, DIR_FLUSH_DOWN})

SERVER = 0xFFFF     # sentinel id for the federator endpoint

FRAME_HEADER_BITS = 16 + 8 + 32 + 8 + 16 + 16 + 16 + 32  # == 144
FRAME_TRAILER_BITS = 32                                   # CRC32
FRAME_OVERHEAD_BITS = FRAME_HEADER_BITS + FRAME_TRAILER_BITS  # == 176
RECONCILE_TOL_BITS = 0.0
# Relative slack for float64 round-off in *booked* bits (not in streams).
RECONCILE_REL_TOL = 1e-9


@dataclass
class Message:
    """One framed payload.  Channels fill direction/sender/recipient and
    the payload; the engine stamps ``round`` and ``scheme_id``."""

    direction: int
    sender: int
    recipient: int
    payload: bytes
    payload_bits: int
    round: int = 0
    scheme_id: int = 0

    def __post_init__(self):
        if self.direction not in _DIRECTIONS:
            raise WireFormatError(f"unknown direction {self.direction}")
        if not (0 <= self.payload_bits <= 8 * len(self.payload)
                < self.payload_bits + 8):
            raise WireFormatError(
                f"payload of {len(self.payload)} bytes cannot carry "
                f"{self.payload_bits} bits (+<8 pad)")

    @property
    def frame_bits(self) -> int:
        """Bits this message occupies on the stream: header, padded
        payload, CRC trailer."""
        return FRAME_HEADER_BITS + 8 * len(self.payload) + FRAME_TRAILER_BITS

    def write_to(self, w: BitWriter) -> None:
        start = w.byte_offset  # frames start byte-aligned by construction
        w.write(MAGIC, 16)
        w.write(VERSION, 8)
        w.write(self.round, 32)
        w.write(self.direction, 8)
        w.write(self.scheme_id, 16)
        w.write(self.sender, 16)
        w.write(self.recipient, 16)
        w.write(self.payload_bits, 32)
        w.write_bits(self.payload, self.payload_bits)
        w.align()
        w.write(w.crc32(start), FRAME_TRAILER_BITS)

    def to_bytes(self) -> bytes:
        """This frame alone as wire bytes (header + payload + CRC)."""
        w = BitWriter()
        self.write_to(w)
        return w.getvalue()

    @classmethod
    def read_from(cls, r: BitReader) -> "Message":
        if r.bits_read % 8:
            raise WireFormatError(
                f"frame must start byte-aligned (bit {r.bits_read})")
        start = r.bits_read // 8
        if r.read(16) != MAGIC:
            raise WireFormatError("bad magic")
        ver = r.read(8)
        if ver != VERSION:
            raise WireFormatError(f"unsupported version {ver}")
        rnd = r.read(32)
        direction = r.read(8)
        scheme_id = r.read(16)
        sender = r.read(16)
        recipient = r.read(16)
        nbits = r.read(32)
        payload, _ = r.read_payload(nbits)
        r.align()
        expected = r.crc32(start, r.bits_read // 8)
        stored = r.read(FRAME_TRAILER_BITS)
        if stored != expected:
            raise WireIntegrityError(
                f"frame CRC mismatch (stored {stored:#010x}, computed "
                f"{expected:#010x}): frame corrupted in flight")
        return cls(direction=direction, sender=sender, recipient=recipient,
                   payload=payload, payload_bits=nbits, round=rnd,
                   scheme_id=scheme_id)

    @classmethod
    def from_bytes(cls, data: bytes) -> "Message":
        """Parse exactly one frame from ``data`` (must consume it fully)."""
        r = BitReader(data)
        m = cls.read_from(r)
        if r.bits_left >= 8:
            raise WireFormatError(
                f"{r.bits_left} bits of trailing garbage after frame")
        return m


@dataclass
class WastedAttempt:
    """One corrupted-in-flight frame copy (retransmission accounting).

    ``frame`` is the *clean* message whose delivery the copy attempted;
    its payload/frame bits are what the retry cost on the wire.  The
    corrupted bytes themselves are not retained -- only their cost and
    the fault position, which is all the accounting needs."""

    frame: Message
    round: int
    attempt: int          # 0-based retry index for this delivery
    flipped_bit: int      # bit position corrupted in the frame copy

    @property
    def payload_bits(self) -> int:
        return self.frame.payload_bits

    @property
    def frame_bits(self) -> int:
        return self.frame.frame_bits


@dataclass
class WireSession:
    """All frames of one engine run, in transmission order.

    ``messages`` holds the *delivered* (clean) traffic that drives the
    trajectory; ``wasted`` holds corrupted copies that forced a
    retransmission (or exhausted the retry budget).  Only ``messages``
    serialize into :meth:`to_bytes` -- a parsed stream must be fully
    intact by construction -- while ``wasted`` reconciles against the
    BitMeter's ``retransmit_bits``."""

    scheme_id: int = 0
    messages: List[Message] = field(default_factory=list)
    wasted: List[WastedAttempt] = field(default_factory=list)

    def add(self, msgs, *, round: int) -> None:
        for m in msgs:
            m.round = round
            m.scheme_id = self.scheme_id
            self.messages.append(m)

    def add_wasted(self, msg: Message, *, round: int, attempt: int,
                   flipped_bit: int) -> None:
        msg.round = round
        msg.scheme_id = self.scheme_id
        self.wasted.append(WastedAttempt(frame=msg, round=round,
                                         attempt=attempt,
                                         flipped_bit=flipped_bit))

    # -- stream (de)serialization -----------------------------------------

    def to_bytes(self) -> bytes:
        w = BitWriter()
        for m in self.messages:
            m.write_to(w)
        return w.getvalue()

    @classmethod
    def parse(cls, data: bytes) -> "WireSession":
        r = BitReader(data)
        out = cls()
        while r.bits_left:
            idx, off = len(out.messages), r.bits_read // 8
            try:
                out.messages.append(Message.read_from(r))
            except WireError as e:
                raise type(e)(
                    f"frame {idx} at byte offset {off}: {e}") from e
            except Exception as e:  # defensive: no bare IndexError escapes
                raise WireFormatError(
                    f"frame {idx} at byte offset {off}: "
                    f"{type(e).__name__}: {e}") from e
        if out.messages:
            out.scheme_id = out.messages[0].scheme_id
        return out

    # -- audit totals ------------------------------------------------------

    def payload_bits(self, directions=None) -> int:
        return sum(m.payload_bits for m in self.messages
                   if directions is None or m.direction in directions)

    @property
    def uplink_payload_bits(self) -> int:
        return self.payload_bits(UPLINK_DIRS)

    @property
    def downlink_payload_bits(self) -> int:
        return self.payload_bits(DOWNLINK_DIRS)

    @property
    def retransmit_payload_bits(self) -> int:
        """Payload bits of every corrupted copy (any direction)."""
        return sum(wa.payload_bits for wa in self.wasted)

    @property
    def retransmit_frame_bits(self) -> int:
        return sum(wa.frame_bits for wa in self.wasted)

    @property
    def stream_bits(self) -> int:
        return sum(m.frame_bits for m in self.messages)

    @property
    def framing_bits(self) -> int:
        """Header + pad + CRC bits: stream length minus payload bits."""
        return self.stream_bits - self.payload_bits()

    def summary(self) -> Dict[str, float]:
        return {
            "messages": len(self.messages),
            "stream_bytes": -(-self.stream_bits // 8),
            "stream_bits": self.stream_bits,
            "payload_bits": self.payload_bits(),
            "uplink_payload_bits": self.uplink_payload_bits,
            "downlink_payload_bits": self.downlink_payload_bits,
            "framing_bits": self.framing_bits,
            "frame_header_bits": FRAME_HEADER_BITS,
            "frame_overhead_bits": FRAME_OVERHEAD_BITS,
            "wasted_messages": len(self.wasted),
            "retransmit_payload_bits": self.retransmit_payload_bits,
            "retransmit_frame_bits": self.retransmit_frame_bits,
        }

    def reconcile(self, meter) -> Dict[str, float]:
        """Audit booked bits against the serialized stream (fails loudly)."""
        report = meter.reconcile(
            self.uplink_payload_bits, self.downlink_payload_bits,
            retransmit_stream_bits=self.retransmit_payload_bits,
            framing_bits=self.framing_bits, n_messages=len(self.messages),
            frame_overhead_bits=FRAME_OVERHEAD_BITS,
            tol_bits=RECONCILE_TOL_BITS, rel_tol=RECONCILE_REL_TOL)
        report.update(self.summary())
        return report
