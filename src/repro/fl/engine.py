"""The one FL round loop: local-train -> uplink -> aggregate -> downlink.

Every training loop in the repo -- the four BiCompFL variants, BiCompFL-CFL,
and all seven non-stochastic baselines -- is an :class:`EngineSpec`
(uplink channel, downlink channel, aggregator, plus block allocation and
participation policy) executed by :class:`FLEngine`.  The engine owns the
things every scheme shares and that used to be copy-pasted per loop:

* shared-randomness key schedule (round key, per-client training keys),
* partial participation (cohort sampling; inactive clients are *not*
  trained),
* the block-allocation control plane,
* periodic error-feedback synchronisation (CSER / LIEC style ``flush``),
* BitMeter accounting and evaluation history.

Two execution paths (tests/test_fused_parity.py; bit-for-bit identical
under static block plans, accuracy/bits-parity within the bucketing bound
under adaptive ones):

* **host** -- a Python round loop dispatching jitted sub-computations.
  Adaptive allocations recompute the *exact* plan from each round's KL
  profile on the host; this path is the parity oracle for the bucketed
  fused execution and the fallback for non-functional channels.
* **fused** -- the entire multi-round run is ONE ``jax.lax.scan`` over
  rounds: channel state (error-feedback memories) is an explicit carry
  pytree threaded through the pure ``step_up`` / ``step_down`` functions,
  evaluation folds in via ``lax.cond`` on the eval schedule, and the EF
  sync flush is a ``lax.cond`` branch.  With a *static* plan the per-round
  bits are data-independent, so communication is booked host-side after
  the scan with zero device round-trips -- the only device->host transfer
  of a whole run is the stacked accuracy vector.  With an *adaptive*
  allocation the round's KL profile is computed on device (the Pallas
  ``bernoulli_kl`` reduction via ``repro.kernels.ops``), a ``lax.switch``
  selects among the allocation's precompiled bucketed plans, and the now
  data-dependent per-round bits ride out of the scan as traced f32 vectors
  that ``BitMeter.book_run`` books after the run.

Cohort sampling is precomputed as a (rounds, n_active) schedule.
``cohort_rng="numpy"`` reproduces the seed's ``default_rng(seed+17)`` draws
(bit-compatible with the legacy loops); ``cohort_rng="jax"`` derives the
cohort from the round key (``fold_in(kt, TAG_COHORT)``), making the whole
run a pure function of ``seed`` with no host RNG.

The engine reproduces the seed loops bit-for-bit at full participation
(tests/test_engine_parity.py); see DESIGN.md for the API contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrc
from repro.core.bernoulli import bern_kl, clip01
from repro.core.bitmeter import BitMeter
from repro.kernels.ops import bernoulli_kl_profile, bernoulli_kl_total
from .channels import (BlockPlan, RoundContext, ServerUpdate, TAG_COHORT,
                       TAG_TRAIN, pin)
from .data import Dataset


def _kl_stats(payload, priors, *, needs_profile: bool) -> Dict[str, Any]:
    """On-device KL statistics for the bucketed adaptive control plane.

    Mirrors the host loop's profile (per-parameter KL of the posterior
    against the client priors, averaged over the active cohort) without
    leaving the device.  On a real accelerator backend both allocation
    flavours run through the Pallas ``bernoulli_kl`` streaming reduction:
    the *mean*-only consumers (``needs_profile=False``,
    e.g. AdaptiveAvgAllocation) take
    ``repro.kernels.ops.bernoulli_kl_total``, and the full-profile
    consumers (``needs_profile=True``, AdaptiveAllocation) take
    ``repro.kernels.ops.bernoulli_kl_profile`` (parameters as kernel
    blocks, clients streaming through the reduction).  In interpret mode
    (CPU) the kernel emulation is orders of magnitude slower than the
    fused XLA elementwise reduction, so the jnp route is used there (the
    kernels' repo-wide convention: interpret=True exists to *validate* on
    CPU, not to run hot loops).  Both routes agree up to f32 summation
    order.
    """
    p = clip01(priors)
    if jax.default_backend() != "cpu":
        if needs_profile:
            klp = bernoulli_kl_profile(payload, p, interpret=False)
            return {"profile": klp, "total": jnp.sum(klp)}
        return {"profile": None,
                "total": bernoulli_kl_total(payload, p, interpret=False)}
    klp = jnp.mean(jax.vmap(bern_kl)(payload, p), axis=0)
    return {"profile": klp if needs_profile else None,
            "total": jnp.sum(klp)}


# ---------------------------------------------------------------------------
# Aggregators: uplink output -> proposed server update.
# ---------------------------------------------------------------------------


class MeanModelAggregator:
    """BiCompFL: the mean of the conveyed posterior samples *is* the model."""

    def __call__(self, ctx, theta, up_out) -> ServerUpdate:
        return ServerUpdate(theta=jnp.mean(up_out, axis=0))


@dataclass
class MeanDeltaAggregator:
    """Conventional FL: average the (compressed) deltas, step the server."""

    server_lr: float = 1.0

    def __call__(self, ctx, theta, up_out) -> ServerUpdate:
        # The mean feeds the server step; pinned so the fused engine cannot
        # FMA-contract mean's scale into the subtraction (cf. channels.pin).
        g = pin(getattr(ctx, "pin_token", None), jnp.mean(up_out, axis=0))
        return ServerUpdate(theta=theta - self.server_lr * g, delta=g,
                            lr=self.server_lr)


# ---------------------------------------------------------------------------
# Engine.
# ---------------------------------------------------------------------------


@dataclass
class EngineSpec:
    """A complete FL scheme: who compresses what, in which direction."""

    uplink: Any
    downlink: Any
    aggregator: Any
    allocation: Any = None       # block-allocation strategy (MRC schemes)
    participation: float = 1.0   # fraction of clients active per round
    sync_period: int = 0         # 0 = never; else flush EF memories every k
    name: str = ""


class FLEngine:
    """Runs an :class:`EngineSpec` against a task and sharded dataset."""

    def __init__(self, task, spec: EngineSpec):
        self.task = task
        self.spec = spec
        # Fused-program cache (satellite of the wire PR): one compiled
        # scanned-run program per (rounds, shapes) signature, so repeated
        # ``run()`` calls -- benchmark sweeps, seed replicates -- stop
        # retracing the scan body.  Each entry holds the jitted runner and
        # the trace-time ``booked`` bit record it captured.
        self._fused_programs: Dict[Any, Any] = {}
        self.fused_trace_count = 0  # bumped at trace time (regression test)

    # -- fused-path eligibility -------------------------------------------

    def fused_supported(self) -> bool:
        """True when the whole run can compile to one scanned XLA program.

        Only *non-functional* channels (no ``step_up`` / ``step_down``
        protocol) force the host loop.  Adaptive allocations are fused via
        their bucketed control plane (``bucket_plans`` / ``select_bucket``
        / ``finalize_plan``); an allocation exposing neither a static plan
        nor the bucket API -- or a hand-built spec combining a
        data-dependent plan with a periodic EF flush, a pairing no
        registry scheme produces (the flush would need the aggregator's
        step size inside every switch branch) -- stays host-only.
        """
        spec = self.spec
        if spec.allocation is not None and \
                not getattr(spec.allocation, "static_plan", False):
            bucket_ok = all(hasattr(spec.allocation, a) for a in
                            ("bucket_plans", "select_bucket", "finalize_plan"))
            if not bucket_ok or spec.sync_period:
                return False
        up_ok = all(hasattr(spec.uplink, a)
                    for a in ("step_up", "init_up_state", "flush_step"))
        dn_ok = all(hasattr(spec.downlink, a)
                    for a in ("step_down", "init_down_state", "flush_step"))
        return up_ok and dn_ok

    # -- cohort schedule ---------------------------------------------------

    @staticmethod
    def cohort_schedule(rounds: int, n: int, n_active: int, seed: int,
                        cohort_rng: str = "numpy") -> np.ndarray:
        """Precompute the (rounds, n_active) active-cohort table.

        ``numpy`` consumes ``default_rng(seed+17)`` exactly as the seed
        loops did (one sorted no-replacement draw per round, in round
        order), so precomputing changes nothing.  ``jax`` derives each
        round's cohort from the shared round key instead.
        """
        if cohort_rng not in ("numpy", "jax"):
            raise ValueError(cohort_rng)
        if n_active >= n:
            return np.tile(np.arange(n, dtype=np.int64), (rounds, 1))
        if cohort_rng == "numpy":
            rng = np.random.default_rng(seed + 17)
            return np.stack([np.sort(rng.choice(n, size=n_active, replace=False))
                             for _ in range(rounds)])
        base = jax.random.PRNGKey(seed)

        def one(t):
            kc = jax.random.fold_in(mrc.round_key(base, t), TAG_COHORT)
            return jnp.sort(jax.random.choice(
                kc, n, (n_active,), replace=False))

        sched = jax.vmap(one)(jnp.arange(rounds))
        return np.asarray(sched, dtype=np.int64)

    # -- entry point -------------------------------------------------------

    def run(self, shards: Dataset, theta0: Optional[jax.Array] = None, *,
            rounds: int, seed: int = 0, eval_every: int = 1,
            mode: str = "auto", cohort_rng: str = "numpy",
            wire: Optional[str] = None) -> Dict[str, Any]:
        """Run the scheme.  ``mode``: "auto" (fused when eligible), "host",
        or "fused" (raises for schemes needing the host control plane).

        ``wire="audit"`` serializes every channel payload through the
        :mod:`repro.wire` bitstream each round (encode -> decode; the
        decoded values drive the trajectory, so the run certifies the
        codecs are lossless) and reconciles the BitMeter against the
        stream; host-path only.  The report lands in ``out["wire"]`` and
        the full stream in ``out["wire_session"]``.
        """
        task, spec = self.task, self.spec
        if wire not in (None, "audit"):
            raise ValueError(f"wire={wire!r} (expected None or 'audit')")
        if wire and mode == "fused":
            raise ValueError("wire audit runs on the host path; it cannot "
                             "be combined with mode='fused'")
        # Stateful channels (error-feedback memories) must start fresh: a
        # spec may be run more than once.
        for chan in (spec.uplink, spec.downlink):
            reset = getattr(chan, "reset", None)
            if reset is not None:
                reset()
        n = int(shards.x.shape[0])
        theta = task.init_theta() if theta0 is None else theta0
        d = int(theta.shape[0])
        theta_hat = jnp.tile(theta[None], (n, 1))
        meter = BitMeter(
            n_clients=n, d=d,
            broadcast_downlink_shareable=getattr(
                spec.downlink, "broadcast_shareable", True))
        n_active = max(1, int(round(spec.participation * n)))
        schedule = self.cohort_schedule(rounds, n, n_active, seed, cohort_rng)

        if mode not in ("auto", "host", "fused"):
            raise ValueError(mode)
        fused_ok = self.fused_supported()
        if mode == "fused" and not fused_ok:
            raise ValueError(
                f"spec {spec.name!r} needs the host control plane "
                "(non-functional channels, an allocation without the bucket "
                "API, or a data-dependent plan combined with an EF flush)")
        fused = fused_ok and mode != "host" and not wire
        if fused:
            out = self._run_fused(shards, theta, theta_hat, meter,
                                  rounds=rounds, seed=seed,
                                  eval_every=eval_every, schedule=schedule)
        else:
            session = None
            if wire:
                from repro.wire import WireSession, scheme_wire_id
                session = WireSession(
                    scheme_id=scheme_wire_id(spec.name or "unnamed"))
            out = self._run_host(shards, theta, theta_hat, meter,
                                 rounds=rounds, seed=seed,
                                 eval_every=eval_every, schedule=schedule,
                                 session=session)
            if session is not None:
                out["wire"] = session.reconcile(meter)
                out["wire_session"] = session
        out["active_schedule"] = schedule
        out["mode"] = "fused" if fused else "host"
        return out

    # -- host loop ---------------------------------------------------------

    def _run_host(self, shards, theta, theta_hat, meter, *, rounds, seed,
                  eval_every, schedule, session=None) -> Dict[str, Any]:
        task, spec = self.task, self.spec
        n, d = meter.n_clients, meter.d
        n_active = schedule.shape[1]
        base = jax.random.PRNGKey(seed)
        history: List[Dict[str, float]] = []
        if session is not None:
            self._check_wire_support()

        for t in range(rounds):
            kt = mrc.round_key(base, t)
            active = schedule[t]
            msgs = []  # this round's wire traffic (audit mode only)

            # ---- local training: only the active cohort ------------------
            train_keys = jax.random.split(jax.random.fold_in(kt, TAG_TRAIN), n)
            if n_active < n:
                priors = theta_hat[active]
                xs, ys, keys = (shards.x[active], shards.y[active],
                                train_keys[active])
            else:  # full participation: no device-side gather/copy needed
                priors, xs, ys, keys = theta_hat, shards.x, shards.y, train_keys
            payload = jax.vmap(task.local_train)(priors, xs, ys, keys)

            # ---- block allocation (host-side control plane) --------------
            plan = None
            if spec.allocation is not None:
                kl = None
                if getattr(spec.allocation, "needs_kl", True):
                    kl = np.asarray(jnp.mean(jax.vmap(bern_kl)(
                        payload, clip01(priors)), axis=0))
                size, n_blocks, seg_ids, overhead = spec.allocation.plan(kl, d)
                plan = BlockPlan(size=size, n_blocks=n_blocks,
                                 seg_ids=seg_ids, overhead_bits=overhead)
                if session is not None:
                    # The plan side information crosses the wire as one CTRL
                    # frame per client (the meter books overhead_bits * n);
                    # the decoded plan -- not the host object -- drives the
                    # round, certifying the header codec.
                    ctrl = self._encode_plan_msgs(plan, n)
                    plan = self._decode_plan_msg(ctrl[0], d)
                    msgs += ctrl

            ctx = RoundContext(t=t, key=kt, n_clients=n, d=d, active=active,
                               plan=plan)

            # ---- uplink -> aggregate -> downlink -------------------------
            if session is None:
                up_out, ul_bits = spec.uplink.transmit(ctx, payload, priors)
            else:
                up_out, ul_bits, up_msgs = spec.uplink.transmit_wire(
                    ctx, payload, priors)
                up_out = spec.uplink.decode_up(ctx, up_msgs, priors)
                msgs += up_msgs
            update = spec.aggregator(ctx, theta, up_out)
            if session is None:
                theta, theta_hat, dl_bits = spec.downlink.distribute(
                    ctx, update, theta, theta_hat)
            else:
                from .channels import WireEnv
                _, dn_msgs = spec.downlink.distribute_wire(
                    ctx, update, theta, theta_hat, up_msgs)
                env = WireEnv(uplink=spec.uplink, aggregator=spec.aggregator,
                              priors=priors, up_msgs=up_msgs, update=update)
                theta, theta_hat, dl_bits = spec.downlink.decode_down(
                    ctx, dn_msgs, theta, theta_hat, env)
                msgs += dn_msgs

            # ---- periodic EF synchronisation (CSER / LIEC) ---------------
            if spec.sync_period and (t + 1) % spec.sync_period == 0:
                if session is None:
                    r_up, b_up = spec.uplink.flush(n, d)
                else:
                    r_up, b_up, fl_msgs = spec.uplink.flush_wire(n, d)
                    if fl_msgs:
                        r_up = spec.uplink.decode_flush_up(fl_msgs, n, d)
                    msgs += fl_msgs
                r_dn, b_dn = spec.downlink.flush(n, d)
                # flush at the aggregator's step size (update.lr), so a
                # hand-built spec cannot desync the reset from the rounds
                theta = theta - update.lr * (r_up + r_dn)
                theta_hat = jnp.tile(theta[None], (n, 1))
                ul_bits += b_up
                dl_bits += b_dn
                if session is not None and b_dn:
                    # The downlink flush re-broadcasts the synced model: n
                    # dense frames of the post-flush theta, n * d * 32 bits
                    # == every stateful downlink's booked flush cost.  The
                    # decoded broadcast drives the trajectory.
                    fd_msgs, theta = self._flush_down_msgs(theta, n, d, b_dn)
                    theta_hat = jnp.tile(theta[None], (n, 1))
                    msgs += fd_msgs

            overhead_bits = plan.overhead_bits * n if plan is not None else 0.0
            meter.add_round(ul_bits, dl_bits, overhead_bits=overhead_bits)
            if session is not None:
                session.add(msgs, round=t)

            if (t + 1) % eval_every == 0 or t == rounds - 1:
                acc = task.evaluate(theta)
                history.append({"round": t + 1, "acc": float(acc),
                                "cum_bits": meter.total_bits,
                                "bpp_so_far": meter.total_bpp})

        return self._result(history, meter, theta, theta_hat)

    # -- wire-audit helpers ------------------------------------------------

    def _check_wire_support(self) -> None:
        spec = self.spec
        missing = [a for a in ("transmit_wire", "decode_up")
                   if not hasattr(spec.uplink, a)]
        missing += [a for a in ("distribute_wire", "decode_down")
                    if not hasattr(spec.downlink, a)]
        if spec.allocation is not None and not all(
                hasattr(spec.allocation, a)
                for a in ("encode_plan", "decode_plan")):
            missing.append("allocation.encode_plan/decode_plan")
        if missing:
            raise ValueError(
                f"spec {spec.name!r} cannot be wire-audited: missing "
                f"{missing}")
        # Fail before any round work: a non-power-of-two n_is books
        # fractional bits per index and would only surface as a
        # WireCapacityError from codecs.index_width mid-run.
        from repro.wire.codecs import WireCapacityError, index_width
        for role, chan in (("uplink", spec.uplink),
                           ("downlink", spec.downlink)):
            n_is = getattr(chan, "n_is", None)
            if n_is is None:
                continue
            try:
                index_width(n_is)
            except WireCapacityError as e:
                raise ValueError(
                    f"spec {spec.name!r} cannot be wire-audited: {role} "
                    f"channel {type(chan).__name__} has n_is={n_is}, "
                    "which books fractional bits per MRC index; wire "
                    "codecs need a power of two") from e

    def _encode_plan_msgs(self, plan, n):
        from repro.wire import DIR_CTRL, BitWriter, SERVER, Message
        w = BitWriter()
        self.spec.allocation.encode_plan(plan, w)
        payload, nbits = w.getvalue(), w.bits_written
        return [Message(direction=DIR_CTRL, sender=cid, recipient=SERVER,
                        payload=payload, payload_bits=nbits)
                for cid in range(n)]

    def _decode_plan_msg(self, msg, d):
        from repro.wire import BitReader
        r = BitReader(msg.payload, msg.payload_bits)
        plan = self.spec.allocation.decode_plan(r, d)
        r.expect_exhausted()
        return plan

    def _flush_down_msgs(self, theta, n, d, b_dn):
        from repro.wire import DIR_FLUSH_DOWN, BitWriter, BitReader, \
            SERVER, Message
        from repro.wire import codecs as wcodecs
        if b_dn != n * d * 32:
            raise ValueError(
                f"downlink flush books {b_dn} bits; the wire layer only "
                f"knows the dense re-broadcast protocol ({n * d * 32} bits)")
        w = BitWriter()
        wcodecs.put_dense(w, np.asarray(theta))
        payload, nbits = w.getvalue(), w.bits_written
        msgs = [Message(direction=DIR_FLUSH_DOWN, sender=SERVER,
                        recipient=cid, payload=payload, payload_bits=nbits)
                for cid in range(n)]
        r = BitReader(msgs[0].payload, msgs[0].payload_bits)
        theta = jnp.asarray(wcodecs.get_dense(r, d))
        r.expect_exhausted()
        return msgs, theta

    # -- fused loop: the whole run is one lax.scan over rounds -------------

    def _build_fused(self, *, rounds, n, d, n_active):
        """Build (jitted runner, trace-time booked-bits record) for one
        run signature.  Everything round-varying (seed key, cohort
        schedule, eval/flush masks, model/dataset arrays) is a runner
        *argument*; the spec, plans and shapes are baked into the trace.
        """
        task, spec = self.task, self.spec
        full = n_active == n
        alloc = spec.allocation
        adaptive = alloc is not None and \
            not getattr(alloc, "static_plan", False)
        if adaptive:
            # Bucketed control plane: one lax.switch branch per static plan.
            plans = alloc.bucket_plans(d)
        elif alloc is not None:  # static: plan once for all rounds
            size, n_blocks, seg_ids, overhead = alloc.plan(None, d)
            plans = [BlockPlan(size=size, n_blocks=n_blocks, seg_ids=seg_ids,
                               overhead_bits=overhead)]
        else:
            plans = [None]

        # Static plans: bits are data-independent, so the single trace of
        # the scan body records the per-round (and per-flush) totals as
        # plain floats and the meter never touches the device.  Adaptive
        # plans: bits depend on the round's bucket, so the scan emits them
        # as traced f32 per-round vectors instead.
        booked: Dict[str, Any] = {}

        # The host loop *materialises* each stage's output between separate
        # dispatches; inside one fused graph XLA instead fuses values into
        # their consumers, where LLVM FMA-contracts mul->sub chains into a
        # single rounding and breaks bit-parity.  Every cross-stage value is
        # therefore pinned through ``channels.pin`` (an integer-space
        # round-trip on a traced zero); the speedup comes from removing
        # per-round dispatch, not from cross-stage fusion.

        def round_with_plan(plan, theta, theta_hat, up_s, dn_s, payload,
                            priors, ctx):
            """Uplink -> aggregate -> downlink at one (static-shape) plan."""
            pp = ctx.pin_token
            up_out, ul_bits, up_s = spec.uplink.step_up(
                ctx, up_s, payload, priors)
            up_out, up_s = pin(pp, (up_out, up_s))
            update = spec.aggregator(ctx, theta, up_out)
            update = ServerUpdate(theta=pin(pp, update.theta),
                                  delta=pin(pp, update.delta)
                                  if update.delta is not None else None,
                                  lr=update.lr)
            res, dn_s = spec.downlink.step_down(
                ctx, dn_s, update, theta, theta_hat)
            theta, theta_hat, dn_s = pin(pp, (res.theta, res.theta_hat, dn_s))
            oh = plan.overhead_bits * n if plan is not None else 0.0
            return theta, theta_hat, up_s, dn_s, update, ul_bits, res.bits, oh

        def run_fn(base, theta0, theta_hat0, sx, sy, xs_all):
            self.fused_trace_count += 1  # Python side effect: trace-time only

            def body(carry, xs):
                theta, theta_hat, up_s, dn_s = carry
                kt = mrc.round_key(base, xs["t"])
                active = xs["active"]
                pp = xs["pin"]  # traced int32 zero: the rounding pin token

                train_keys = jax.random.split(
                    jax.random.fold_in(kt, TAG_TRAIN), n)
                if full:
                    priors, bx, by, keys = theta_hat, sx, sy, train_keys
                else:
                    priors = theta_hat[active]
                    bx, by, keys = sx[active], sy[active], train_keys[active]
                payload = pin(pp, jax.vmap(task.local_train)(
                    priors, bx, by, keys))

                def make_ctx(plan):
                    return RoundContext(t=xs["t"], key=kt, n_clients=n, d=d,
                                        active=active, plan=plan,
                                        pin_token=pp)

                if adaptive:
                    stats = _kl_stats(payload, priors,
                                      needs_profile=getattr(
                                          alloc, "needs_profile", True))
                    bidx = alloc.select_bucket(stats, d)

                    def make_branch(template):
                        def branch(op):
                            th, thh, us, ds = op
                            plan = alloc.finalize_plan(template, stats, d)
                            th, thh, us, ds, _, ulb, dlb, oh = \
                                round_with_plan(plan, th, thh, us, ds,
                                                payload, priors,
                                                make_ctx(plan))
                            bits = tuple(jnp.asarray(b, jnp.float32)
                                         for b in (ulb, dlb, oh))
                            return th, thh, us, ds, bits
                        return branch

                    theta, theta_hat, up_s, dn_s, bits = jax.lax.switch(
                        bidx, [make_branch(p) for p in plans],
                        (theta, theta_hat, up_s, dn_s))
                else:
                    theta, theta_hat, up_s, dn_s, update, ul_bits, dl_bits, \
                        oh = round_with_plan(plans[0], theta, theta_hat,
                                             up_s, dn_s, payload, priors,
                                             make_ctx(plans[0]))
                    booked["round"] = (ul_bits, dl_bits, oh)
                    bits = ()

                    if spec.sync_period:
                        def do_flush(op):
                            th, thh, us, ds = op
                            r_up, b_up, us = spec.uplink.flush_step(us, n, d)
                            r_dn, b_dn, ds = spec.downlink.flush_step(
                                ds, n, d)
                            booked["flush"] = (b_up, b_dn)
                            # residual means
                            r_up, r_dn = pin(pp, (r_up, r_dn))
                            th = th - update.lr * (r_up + r_dn)
                            return pin(pp, (th, jnp.tile(th[None], (n, 1)),
                                            us, ds))

                        theta, theta_hat, up_s, dn_s = jax.lax.cond(
                            xs["flush"], do_flush, lambda op: op,
                            (theta, theta_hat, up_s, dn_s))

                acc = jax.lax.cond(
                    xs["eval"],
                    lambda th: jnp.asarray(task.evaluate(th), jnp.float32),
                    lambda th: jnp.full((), jnp.nan, jnp.float32), theta)
                return (theta, theta_hat, up_s, dn_s), (acc,) + bits

            carry0 = (theta0, theta_hat0,
                      spec.uplink.init_up_state(n, d),
                      spec.downlink.init_down_state(n, d))
            (theta, theta_hat, _, _), outs = jax.lax.scan(
                body, carry0, xs_all)
            return (theta, theta_hat), outs

        return jax.jit(run_fn), booked

    def _run_fused(self, shards, theta, theta_hat, meter, *, rounds, seed,
                   eval_every, schedule) -> Dict[str, Any]:
        spec = self.spec
        n, d = meter.n_clients, meter.d
        n_active = schedule.shape[1]
        alloc = spec.allocation
        adaptive = alloc is not None and \
            not getattr(alloc, "static_plan", False)

        eval_mask = np.zeros(rounds, bool)
        eval_mask[eval_every - 1::eval_every] = True
        if rounds:
            eval_mask[-1] = True
        flush_mask = np.zeros(rounds, bool)
        if spec.sync_period:
            flush_mask[spec.sync_period - 1::spec.sync_period] = True

        # One compiled program per run signature: the seed, cohort schedule
        # and eval/flush masks ride in as *data*, so seed replicates and
        # eval-cadence changes hit the cache; only a shape change (rounds,
        # client count, model size, dataset shard dims) builds a new
        # program.
        sig = (rounds, n, d, n_active,
               tuple(shards.x.shape), str(shards.x.dtype),
               tuple(shards.y.shape), str(shards.y.dtype),
               tuple(theta.shape), str(theta.dtype))
        prog = self._fused_programs.get(sig)
        if prog is None:
            prog = self._build_fused(rounds=rounds, n=n, d=d,
                                     n_active=n_active)
            self._fused_programs[sig] = prog
        fn, booked = prog

        xs = {"t": jnp.arange(rounds, dtype=jnp.int32),
              "active": jnp.asarray(schedule),
              "eval": jnp.asarray(eval_mask),
              "flush": jnp.asarray(flush_mask),
              "pin": jnp.zeros(rounds, jnp.int32)}
        (theta, theta_hat), outs = fn(jax.random.PRNGKey(seed), theta,
                                      theta_hat, shards.x, shards.y, xs)

        if adaptive:
            # Traced-bits booking: the scan's stacked per-round bit totals
            # are the only extra device->host transfer.  They are exact as
            # long as they stay below 2**24 -- every term is an integer
            # times log2 of a pow2 n_is, and f32 represents integers
            # exactly up to there -- so guard the bound loudly instead of
            # letting the accounting drift silently at larger scales.
            accs, ul, dl, oh = (np.asarray(o) for o in outs)
            if max((float(np.max(np.abs(v))) if v.size else 0.0)
                   for v in (ul, dl, oh)) >= 2.0 ** 24:
                raise OverflowError(
                    "per-round traced bits exceed the f32 integer-exact "
                    "range (2**24); run mode='host' for exact accounting "
                    "at this scale")
            snaps = meter.book_run(np.asarray(ul, np.float64),
                                   np.asarray(dl, np.float64),
                                   overhead_bits=np.asarray(oh, np.float64),
                                   snapshot_mask=eval_mask)
        else:
            # Host-side booking with zero device involvement.
            (accs,) = outs
            accs = np.asarray(accs)
            ul_base, dl_base, oh = booked["round"]
            fl_up, fl_dn = booked.get("flush", (0.0, 0.0))
            snaps = meter.book_run(
                [ul_base + (fl_up if flush_mask[t] else 0.0)
                 for t in range(rounds)],
                [dl_base + (fl_dn if flush_mask[t] else 0.0)
                 for t in range(rounds)],
                overhead_bits=oh, snapshot_mask=eval_mask)
        history: List[Dict[str, float]] = [
            {"round": int(t) + 1, "acc": float(accs[t]),
             "cum_bits": cum_bits, "bpp_so_far": bpp}
            for t, (cum_bits, bpp) in zip(np.nonzero(eval_mask)[0], snaps)]
        return self._result(history, meter, theta, theta_hat)

    @staticmethod
    def _result(history, meter, theta, theta_hat) -> Dict[str, Any]:
        return {"history": history, "meter": meter.summary(),
                "theta": theta, "theta_hat": theta_hat,
                "final_acc": history[-1]["acc"] if history else float("nan"),
                "max_acc": max(h["acc"] for h in history)
                if history else float("nan")}


def run_spec(task, spec: EngineSpec, shards: Dataset,
             theta0: Optional[jax.Array] = None, *, rounds: int,
             seed: int = 0, eval_every: int = 1, mode: str = "auto",
             cohort_rng: str = "numpy") -> Dict[str, Any]:
    """Convenience one-shot: build an engine and run it."""
    return FLEngine(task, spec).run(shards, theta0, rounds=rounds, seed=seed,
                                    eval_every=eval_every, mode=mode,
                                    cohort_rng=cohort_rng)
