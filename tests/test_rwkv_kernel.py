"""Pallas chunked-RWKV kernel vs the model's exact implementations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import rwkv6

KEY = jax.random.PRNGKey(31)


def _streams(b=2, s=128, h=2, dh=64, key=KEY, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (b, s, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, h, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, h, dh), dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, dh)) - 2.0)
    u = (jax.random.normal(jax.random.fold_in(key, 5), (h, dh)) * 0.1)
    return r, k, v, logw, u


@pytest.mark.parametrize("s", [64, 128, 100])   # aligned + ragged
def test_kernel_matches_sequential(s):
    r, k, v, logw, u = _streams(s=s)
    s0 = jnp.zeros((r.shape[0], r.shape[2], 64, 64))
    o_ref, _ = rwkv6._time_mix_sequential(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        logw, u, s0)
    o_k = ops.rwkv_time_mix(r, k, v, logw.astype(r.dtype), u)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=3e-4, atol=3e-4)


def test_kernel_matches_xla_chunked():
    r, k, v, logw, u = _streams(s=128)
    s0 = jnp.zeros((2, 2, 64, 64))
    o_xla, _ = rwkv6._time_mix_chunked(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        logw, u, s0, chunk=64)
    o_k = ops.rwkv_time_mix(r, k, v, logw.astype(r.dtype), u)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_xla),
                               rtol=3e-4, atol=3e-4)


def test_bfloat16_inputs():
    r, k, v, logw, u = _streams(s=64, dtype=jnp.bfloat16)
    s0 = jnp.zeros((2, 2, 64, 64))
    o_ref, _ = rwkv6._time_mix_sequential(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        logw, u, s0)
    o_k = ops.rwkv_time_mix(r, k, v, logw.astype(jnp.bfloat16), u)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_strong_decay_finite():
    r, k, v, logw, u = _streams(s=64)
    logw = jnp.full_like(logw, -15.0)
    o_k = ops.rwkv_time_mix(r, k, v, logw, u)
    assert bool(jnp.all(jnp.isfinite(o_k)))
