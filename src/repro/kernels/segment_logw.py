"""Pallas TPU kernel: segment MRC importance log-weights.

The adaptive (variable-block) codec evaluates

    logW[i, s] = sum_{e in seg s} x_{ie} * a_e  +  sum_{e in seg s} b_e

for every candidate row i and segment s.  The jnp route
(``core.mrc.default_segment_logw``) materialises the full (n_is, d)
``xa = where(u < p, a, 0)`` tensor in HBM and runs a vmapped
``segment_sum``.  Here the candidate uniforms stream through VMEM once:
each (TILE_I, TILE_D) tile of ``u`` is compared against the prior row and
selected against ``a`` in registers, then reduced per segment on the MXU
via a one-hot segment matrix

    M[e, s] = (seg_ids[e] == s)          (TILE_D, NSEG)

so the per-tile partial is the matmul ``xa_tile @ M`` (exact: M is 0/1 and
xa is finite, so the dot is a masked sum, not an approximation).  The
candidate-independent prior term folds in as ``b_tile @ M`` on the same
one-hot.  Partials accumulate in a VMEM scratch block across the
sequential d-grid dimension and the (TILE_I, NSEG) result is written out
once on the last d-tile -- the (n_is, d) ``xa`` tensor never exists in HBM.

Grid: (NIS/TILE_I, D/TILE_D); the d axis is innermost, so each i-tile sees
its d-tiles back to back and the scratch accumulator carries cleanly.
VMEM working set per step: 128*128*4 (u) + 4*128*4 (p, a, b, seg) +
2*128*NSEG*4 (one-hot + scratch) -- ~1.2 MiB at NSEG=512, well under the
16 MiB VMEM budget for the model sizes adaptive allocation targets.
Shapes must be pre-padded (``ops.segment_logw`` is the general-shape entry
point and documents the padding contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_I = 128   # candidate-row tile (MXU sublane dim)
TILE_D = 128   # parameter tile (MXU lane dim)
NSEG_LANE = 128  # segment axis must pad to the lane width


def _segment_logw_kernel(u_ref, p_ref, a_ref, b_ref, seg_ref, o_ref, acc_ref):
    """One (i_tile, d_tile) grid step."""
    k = pl.program_id(1)
    n_k = pl.num_programs(1)
    nseg = o_ref.shape[1]

    seg = seg_ref[0]                                   # (TILE_D,) int32
    onehot = (seg[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (seg.shape[0], nseg), 1)).astype(jnp.float32)
    # Fused compare + select: x is {0,1}, so x*a == where(u < p, a, 0).
    xa = jnp.where(u_ref[...] < p_ref[0][None, :], a_ref[0][None, :], 0.0)
    part = jnp.dot(xa, onehot, preferred_element_type=jnp.float32)
    part = part + jnp.dot(b_ref[...], onehot,
                          preferred_element_type=jnp.float32)  # (1, nseg) bcast

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = part

    @pl.when(k != 0)
    def _acc():
        acc_ref[...] = acc_ref[...] + part

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("n_seg", "interpret"))
def segment_logw_pallas(u: jax.Array, p: jax.Array, a: jax.Array,
                        b: jax.Array, seg_ids: jax.Array, *, n_seg: int,
                        interpret: bool = True) -> jax.Array:
    """Per-segment importance log-weights for tile-aligned shapes.

    u: (NIS, D) uniforms; p, a, b: (1, D) f32; seg_ids: (1, D) int32 with
    values in [0, n_seg).  Returns (NIS, n_seg) f32.  Requires
    NIS % TILE_I == 0, D % TILE_D == 0 and n_seg % NSEG_LANE == 0 (use
    ``ops.segment_logw`` for the padded general-shape entry point).
    """
    nis, d = u.shape
    if nis % TILE_I != 0 or d % TILE_D != 0 or n_seg % NSEG_LANE != 0:
        raise ValueError(
            f"segment_logw_pallas needs NIS % {TILE_I} == 0, D % {TILE_D} "
            f"== 0 and n_seg % {NSEG_LANE} == 0; got NIS={nis}, D={d}, "
            f"n_seg={n_seg} (use ops.segment_logw for general shapes)")
    grid = (nis // TILE_I, d // TILE_D)
    return pl.pallas_call(
        _segment_logw_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_I, TILE_D), lambda i, k: (i, k)),
            pl.BlockSpec((1, TILE_D), lambda i, k: (0, k)),
            pl.BlockSpec((1, TILE_D), lambda i, k: (0, k)),
            pl.BlockSpec((1, TILE_D), lambda i, k: (0, k)),
            pl.BlockSpec((1, TILE_D), lambda i, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((TILE_I, n_seg), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nis, n_seg), jnp.float32),
        scratch_shapes=[pltpu.VMEM((TILE_I, n_seg), jnp.float32)],
        interpret=interpret,
    )(u, p, a, b, seg_ids)
