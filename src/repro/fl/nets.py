"""Small pure-JAX classifier networks for the FL experiments.

Bias-free CNN/MLP families mirroring the paper's LeNet5 / 4CNN / 6CNN
(scaled to the synthetic datasets).  For probabilistic-mask training the
weights use the *signed-constant* initialization of Ramanujan et al. (2020):
w = sign(n) * std_kaiming -- the setting in which random subnetworks are
known to be expressive.
"""
from __future__ import annotations

import math
from typing import Callable, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


class Net(NamedTuple):
    init: Callable[[jax.Array], list]
    apply: Callable[[list, jax.Array], jax.Array]  # (weights, x NHWC) -> logits


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _kaiming_signed(key, shape, fan_in, signed_constant: bool):
    std = math.sqrt(2.0 / fan_in)
    w = jax.random.normal(key, shape)
    if signed_constant:
        return jnp.sign(w) * std
    return w * std


def make_cnn(
    hw: int = 14,
    channels: int = 1,
    n_classes: int = 10,
    conv_widths: Sequence[int] = (32, 64),
    dense_widths: Sequence[int] = (128,),
    signed_constant: bool = False,
) -> Net:
    """Conv(3x3)+ReLU+MaxPool blocks, then dense head. Bias-free."""
    n_pools = len(conv_widths)
    final_hw = hw // (2 ** n_pools)
    assert final_hw >= 1, "too many pools for input size"

    shapes: List[Tuple[Tuple[int, ...], int]] = []  # (shape, fan_in)
    cin = channels
    for w_ in conv_widths:
        shapes.append(((3, 3, cin, w_), 3 * 3 * cin))
        cin = w_
    flat = final_hw * final_hw * cin
    din = flat
    for w_ in dense_widths:
        shapes.append(((din, w_), din))
        din = w_
    shapes.append(((din, n_classes), din))

    def init(key):
        keys = jax.random.split(key, len(shapes))
        return [_kaiming_signed(k, s, f, signed_constant) for k, (s, f) in zip(keys, shapes)]

    n_conv = len(conv_widths)

    def apply(weights, x):
        h = x
        for i in range(n_conv):
            h = _maxpool(jax.nn.relu(_conv(h, weights[i])))
        h = h.reshape(h.shape[0], -1)
        for w_ in weights[n_conv:-1]:
            h = jax.nn.relu(h @ w_)
        return h @ weights[-1]

    return Net(init=init, apply=apply)


def make_mlp(
    in_dim: int, widths: Sequence[int] = (256, 256), n_classes: int = 10,
    signed_constant: bool = False,
) -> Net:
    dims = [in_dim, *widths, n_classes]

    def init(key):
        keys = jax.random.split(key, len(dims) - 1)
        return [
            _kaiming_signed(k, (a, b), a, signed_constant)
            for k, a, b in zip(keys, dims[:-1], dims[1:])
        ]

    def apply(weights, x):
        h = x.reshape(x.shape[0], -1)
        for w_ in weights[:-1]:
            h = jax.nn.relu(h @ w_)
        return h @ weights[-1]

    return Net(init=init, apply=apply)


def flatten_weights(weights) -> Tuple[jax.Array, Callable]:
    return ravel_pytree(weights)


def cross_entropy(logits: jax.Array, y: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(apply_fn, weights, x, y, batch: int = 1000) -> jax.Array:
    """Mean top-1 accuracy as a float32 scalar array.

    Fully traceable (no host round-trips), so ``task.evaluate`` can run
    under ``lax.cond`` inside the engine's fused round scan.  Large test
    sets are processed in ``batch``-row chunks via ``lax.map`` so the
    logits tensor never exceeds one chunk.
    """
    n = x.shape[0]
    if n <= batch:
        correct = jnp.sum(
            (jnp.argmax(apply_fn(weights, x), -1) == y).astype(jnp.float32))
        return correct * jnp.float32(1.0 / n)
    nb = -(-n // batch)
    pad = nb * batch - n
    xp = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    yp = jnp.pad(y, (0, pad), constant_values=-1)  # -1 never equals an argmax

    def chunk(i):
        xi = jax.lax.dynamic_slice_in_dim(xp, i * batch, batch)
        yi = jax.lax.dynamic_slice_in_dim(yp, i * batch, batch)
        return jnp.sum(
            (jnp.argmax(apply_fn(weights, xi), -1) == yi).astype(jnp.float32))

    # Multiply by the reciprocal instead of dividing: XLA rewrites a
    # divide-by-constant to a reciprocal multiply in *some* programs, so an
    # explicit mul is the only form that rounds identically inside the
    # engine's fused scan and in the standalone host-loop eval.
    return jnp.sum(jax.lax.map(chunk, jnp.arange(nb))) * jnp.float32(1.0 / n)
