"""JIT'd general-shape wrappers around the Pallas kernels.

These pad arbitrary shapes to the kernels' tile alignment, invoke the
kernel, and slice the result back.  ``interpret`` defaults to True so the
kernels execute (and are validated) on CPU; on a real TPU pass
``interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bernoulli_kl import TILE_S as KL_TILE_S, bernoulli_kl_pallas
from .mrc_weights import TILE_I, TILE_S, mrc_logw_pallas


def _pad_axis(x: jax.Array, axis: int, mult: int, value: float = 0.0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mrc_logw(x: jax.Array, a: jax.Array, b: jax.Array, *, interpret: bool = True):
    """logW = X @ a + sum(b); x (NB, NIS, S), a/b (NB, S) -> (NB, NIS).

    Zero-padding is exact: padded entries contribute x*0 + 0 to the sums.
    Drop-in replacement for ``repro.core.mrc.default_logw`` (as ``logw_fn``).
    """
    nis, s = x.shape[1], x.shape[2]
    xp = _pad_axis(_pad_axis(x.astype(jnp.float32), 1, TILE_I), 2, TILE_S)
    ap = _pad_axis(a.astype(jnp.float32), 1, TILE_S)
    bp = _pad_axis(b.astype(jnp.float32), 1, TILE_S)
    out = mrc_logw_pallas(xp, ap, bp, interpret=interpret)
    return out[:, :nis]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bernoulli_kl(q: jax.Array, p: jax.Array, *, interpret: bool = True):
    """Per-block KL(q||p) sums; q, p (NB, S) -> (NB,) nats.

    Pads with q == p == 0.5 (zero KL), so the padded sum is exact.
    """
    qp = _pad_axis(q.astype(jnp.float32), 1, KL_TILE_S, value=0.5)
    pp = _pad_axis(p.astype(jnp.float32), 1, KL_TILE_S, value=0.5)
    return bernoulli_kl_pallas(qp, pp, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_seg", "interpret"))
def segment_logw(u: jax.Array, p: jax.Array, a: jax.Array, b: jax.Array,
                 seg_ids: jax.Array, *, n_seg: int, interpret: bool = True):
    """Segment MRC log-weights; u (NIS, D), p/a/b/seg_ids (D,) -> (NIS, n_seg).

    Drop-in replacement for ``repro.core.mrc.default_segment_logw`` (as
    ``seg_logw_fn``).  Padding contract: padded ``u`` entries carry 1.0
    against a padded prior of 0.0 (the compare is strictly ``u < p``, so
    they never select), padded ``a``/``b`` are 0 and padded ``seg_ids``
    point at segment 0 -- every pad contributes exactly 0 to its segment
    sum, and the padded candidate rows / segment columns are sliced off.
    """
    from .segment_logw import NSEG_LANE, TILE_D, TILE_I, segment_logw_pallas
    nis, d = u.shape
    up = _pad_axis(_pad_axis(u.astype(jnp.float32), 0, TILE_I, value=1.0),
                   1, TILE_D, value=1.0)
    pp = _pad_axis(p.astype(jnp.float32)[None], 1, TILE_D)
    ap = _pad_axis(a.astype(jnp.float32)[None], 1, TILE_D)
    bp = _pad_axis(b.astype(jnp.float32)[None], 1, TILE_D)
    sp = _pad_axis(seg_ids.astype(jnp.int32)[None], 1, TILE_D)
    nseg_pad = n_seg + (-n_seg) % NSEG_LANE
    out = segment_logw_pallas(up, pp, ap, bp, sp, n_seg=nseg_pad,
                              interpret=interpret)
    return out[:nis, :n_seg]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bernoulli_kl_total(q: jax.Array, p: jax.Array, *, interpret: bool = True):
    """Mean-over-clients total KL(q||p): q, p (n, d) -> f32 scalar (nats).

    The per-(client, block) partial sums run through the Pallas streaming
    reduction (``bernoulli_kl_pallas``); rows pad with q == p == 0.5 (zero
    KL), so the padded result is exact.  This is the on-device profile
    statistic the fused engine feeds ``AdaptiveAvgAllocation`` --
    mean_i sum_e KL equals sum_e mean_i KL, which is what the host control
    plane computed from numpy.
    """
    n, d = q.shape
    nb = -(-d // KL_TILE_S)
    qp = _pad_axis(q.astype(jnp.float32), 1, KL_TILE_S, value=0.5)
    pp = _pad_axis(p.astype(jnp.float32), 1, KL_TILE_S, value=0.5)
    sums = bernoulli_kl_pallas(qp.reshape(n * nb, KL_TILE_S),
                               pp.reshape(n * nb, KL_TILE_S),
                               interpret=interpret)
    return jnp.sum(sums) / n


@functools.partial(jax.jit, static_argnames=("interpret",))
def bernoulli_kl_profile(q: jax.Array, p: jax.Array, *, interpret: bool = True):
    """Per-parameter cohort-mean KL(q||p): q, p (n, d) -> (d,) nats.

    Transposes so each *parameter* becomes one kernel block and the client
    axis streams through the Pallas reduction; the client axis pads with
    q == p == 0.5 (zero KL), so the padded per-parameter sums are exact and
    dividing by the true cohort size recovers the mean.  This is the
    on-device profile statistic the fused engine feeds
    ``AdaptiveAllocation`` (matching ``jnp.mean(vmap(bern_kl), axis=0)`` up
    to f32 summation order).
    """
    n = q.shape[0]
    qp = _pad_axis(q.astype(jnp.float32).T, 1, KL_TILE_S, value=0.5)
    pp = _pad_axis(p.astype(jnp.float32).T, 1, KL_TILE_S, value=0.5)
    return bernoulli_kl_pallas(qp, pp, interpret=interpret) / n


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float = 1.0, interpret: bool = True) -> jax.Array:
    """General-shape flash attention.

    q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh) -- GQA kv heads are repeated,
    heads fold into the batch dim, Sq/Skv/Dh pad to the kernel tiles.
    Returns (B, Sq, H, Dh).
    """
    from .flash_attn import BK, BQ, flash_attention_pallas
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    # (B, S, H, Dh) -> (B*H, S, Dh)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, dh)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, skv, dh)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, skv, dh)
    qp = _pad_axis(_pad_axis(qf, 1, BQ), 2, 128)
    kp = _pad_axis(_pad_axis(kf, 1, BK), 2, 128)
    vp = _pad_axis(_pad_axis(vf, 1, BK), 2, 128)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 scale=scale, skv=skv, interpret=interpret)
    out = out[:, :sq, :dh].reshape(b, h, sq, dh)
    return jnp.moveaxis(out, 1, 2)


def rwkv_time_mix(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
                  u: jax.Array, *, interpret: bool = True) -> jax.Array:
    """General-shape chunked RWKV-6 time-mix (zero initial state).

    r/k/v/logw: (B, S, H, Dh); u: (H, Dh).  Returns (B, S, H, Dh).
    Sequence pads to the kernel chunk; heads fold into the batch dim.
    """
    from .rwkv_chunk import CHUNK, rwkv_chunk_pallas
    b, s, h, dh = r.shape

    def fold(t):  # (B, S, H, Dh) -> (B*H, S_pad, Dh)
        t = jnp.moveaxis(t, 2, 1).reshape(b * h, s, dh)
        return _pad_axis(t, 1, CHUNK)

    # pad value 0 is safe: logw 0 => decay 1, r/k/v 0 contribute nothing
    rf, kf, vf, lwf = fold(r), fold(k), fold(v), fold(logw)
    uf = jnp.tile(u[None], (b, 1, 1)).reshape(b * h, 1, dh)
    out = rwkv_chunk_pallas(rf, kf, vf, lwf, uf, interpret=interpret)
    out = out[:, :s].reshape(b, h, s, dh)
    return jnp.moveaxis(out, 1, 2)


@functools.lru_cache(maxsize=None)
def mrc_logw_fn(interpret: bool = True):
    """Return a ``logw_fn`` closure for ``repro.core.mrc.encode_fixed``.

    Cached per ``interpret`` value: ``encode_fixed`` treats ``logw_fn`` as
    a static jit argument (hashed by identity), so handing out a fresh
    closure per call would force a retrace per channel construction.
    """
    def fn(x, a, b):
        return mrc_logw(x, a, b, interpret=interpret)
    return fn


@functools.lru_cache(maxsize=None)
def segment_logw_fn(interpret: bool = True):
    """Return a ``seg_logw_fn`` closure for ``repro.core.mrc.encode_segments``.

    Cached per ``interpret`` value for the same reason as ``mrc_logw_fn``:
    the encoder treats the hook as a static jit argument hashed by
    identity, so a fresh closure per call would retrace.
    """
    def fn(u, p, a, b, seg_ids, n_seg):
        return segment_logw(u, p, a, b, seg_ids, n_seg=n_seg,
                            interpret=interpret)
    return fn
