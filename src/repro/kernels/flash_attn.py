"""Pallas TPU kernel: flash attention (lazy-softmax, VMEM-resident tiles).

Motivation (EXPERIMENTS.md §Perf, qwen3-14b x train_4k): the XLA lowering of
chunked attention materialises the (Sq, C) score tensor ~8 times per chunk
(where -> max -> exp -> correction -> PV), ~35% of the step's HBM traffic.
On TPU the fix is the canonical flash kernel: scores live in VMEM tiles and
never reach HBM; per-row (max, denominator) run in f32 scratch.

Layout: inputs are pre-flattened to (BH, S, Dh) (GQA kv heads repeated by
the ops.py wrapper).  Grid (BH, Sq/BQ, Skv/BK); the kv axis is the innermost
(sequential) grid dim, accumulating into VMEM scratch; the output tile is
written on the last kv step.

VMEM working set per step: q,k,v tiles 3*128*128*4 + acc 128*128*4 + m/l
2*128*4 ~ 256 KiB << 16 MiB.  MXU dims are 128-aligned by ops.py padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128   # query-row tile
BK = 128   # kv-row tile
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, scale: float, skv: int, nk: int):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, Dh)
    k = k_ref[0].astype(jnp.float32)                  # (BK, Dh)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (BQ, BK)

    qpos = qi * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
    kpos = ki * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
    mask = kpos < skv                                  # non-pad
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "skv", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool, window: int, scale: float,
                           skv: int, interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, Dh); k, v: (BH, Skv_pad, Dh); 128-aligned shapes.

    ``skv`` is the unpadded kv length (mask boundary).  Use
    ``ops.flash_attention`` for the general-shape entry point.
    """
    bh, sq, dh = q.shape
    skv_pad = k.shape[1]
    if sq % BQ != 0 or skv_pad % BK != 0 or dh % 128 != 0:
        raise ValueError(
            f"flash_attention_pallas needs Sq % {BQ} == 0, Skv_pad % {BK} "
            f"== 0 and Dh % 128 == 0, got Sq={sq}, Skv_pad={skv_pad}, "
            f"Dh={dh} (use ops.flash_attention for the padded entry point)")
    nq, nk = sq // BQ, skv_pad // BK
    grid = (bh, nq, nk)
    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               scale=scale, skv=skv, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQ, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BK, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BK, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            # f32 running max / denominator / accumulator in VMEM
            pltpu.VMEM((BQ,), jnp.float32),
            pltpu.VMEM((BQ,), jnp.float32),
            pltpu.VMEM((BQ, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
