"""Benchmark harness -- one benchmark per paper table/figure.

  table_main     Tables 5-12 / Figs 1-2: max accuracy + bpp (total, BC,
                 uplink, downlink) per scheme, iid and non-iid, for the
                 BiCompFL variants and the non-stochastic baselines.
  table_cfl      Section 4 (BiCompFL-GR-CFL): conventional FL with
                 stochastic sign + MRC vs the sign-EF baselines.
  ablation_ndl   Appendix J.3: downlink sample count n_DL.
  ablation_nis   Appendix J.5: importance samples n_IS.
  ablation_block Appendix J.4: block size d/B.
  ablation_nclients  Appendix J.1: number of clients.
  kernel_micro   Pallas kernel (interpret) vs jnp oracle timing + allclose.
  wire_audit     bytes on the wire per scheme: short wire-audited host runs
                 over the full registry matrix (stream bytes per round,
                 payload vs framing split; reconcile runs inside).
  roofline       reads dryrun_*.json -> the per-(arch x shape x mesh) table.

Run:  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import AdaptiveAllocation, AdaptiveAvgAllocation, FixedAllocation
from repro.fl import registry
from repro.fl.data import make_synthetic, partition_dirichlet, partition_iid
from repro.fl.engine import FLEngine
from repro.fl.nets import make_cnn, make_mlp
from repro.fl.tasks import make_cfl_task, make_mask_task

SEP = "-" * 100


def _setup(seed=0, *, iid=True, n_clients=4, hw=10, noise=0.5,
           n_train=1600, n_test=400):
    k = jax.random.PRNGKey(seed)
    train, test = make_synthetic(k, n_train=n_train, n_test=n_test, hw=hw,
                                 noise=noise)
    shard = n_train // n_clients
    if iid:
        shards = partition_iid(jax.random.fold_in(k, 1), train, n_clients, shard)
    else:
        shards = partition_dirichlet(jax.random.fold_in(k, 1), train,
                                     n_clients, shard, alpha=0.1)
    return k, shards, test


def _mask_task(k, test, hw=10, width=256, local_epochs=3, lr=0.1):
    net = make_mlp(in_dim=hw * hw, widths=(width,), signed_constant=True)
    return make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                          local_epochs=local_epochs, lr=lr)


def _fmt_row(name, out):
    m = out["meter"]
    return (f"{name:34s} acc={out['max_acc']:.3f}  bpp={m['bpp']:8.4f} "
            f"bpp(BC)={m['bpp_bc']:8.4f}  up={m['uplink_bpp']:7.4f} "
            f"down={m['downlink_bpp']:7.4f}")


def table_main(fast: bool):
    """Main accuracy-vs-bitrate table (paper Tables 5-12)."""
    rounds = 6 if fast else 10
    for iid in (True, False):
        print(f"\n== table_main ({'iid' if iid else 'non-iid Dir(0.1)'}), "
              f"{rounds} rounds, 4 clients, synthetic-10class ==")
        k, shards, test = _setup(iid=iid)
        task = _mask_task(k, test)

        n = int(shards.x.shape[0])  # n_dl paper default = n_clients * n_ul
        variants = [
            ("BiCompFL-GR-Fixed",
             registry.bicompfl_spec("GR", allocation=FixedAllocation(128),
                                    n_is=64, n_dl=n)),
            ("BiCompFL-GR-Adaptive",
             registry.bicompfl_spec("GR", allocation=AdaptiveAllocation(n_is=64),
                                    n_is=64, n_dl=n)),
            ("BiCompFL-GR-Adaptive-Avg",
             registry.bicompfl_spec("GR", allocation=AdaptiveAvgAllocation(n_is=64),
                                    n_is=64, n_dl=n)),
            ("BiCompFL-GR-Reconst-Fixed",
             registry.bicompfl_spec("GR-Reconst", allocation=FixedAllocation(128),
                                    n_is=64, n_dl=n)),
            ("BiCompFL-PR-Fixed",
             registry.bicompfl_spec("PR", allocation=FixedAllocation(128),
                                    n_is=64, n_dl=n)),
            ("BiCompFL-PR-Fixed-SplitDL",
             registry.bicompfl_spec("PR-SplitDL", allocation=FixedAllocation(128),
                                    n_is=64, n_dl=n)),
        ]
        for name, spec in variants:
            t0 = time.time()
            out = FLEngine(task, spec).run(shards, rounds=rounds, seed=0)
            print(_fmt_row(name, out) + f"  [{time.time()-t0:.0f}s]", flush=True)
            jax.clear_caches()  # the CPU JIT otherwise exhausts memory
                                # across variants (LLVM 'Cannot allocate')

        # conventional baselines need a CFL task (deterministic weights)
        net = make_mlp(in_dim=100, widths=(256,))
        ctask, theta0 = make_cfl_task(net, jax.random.fold_in(k, 3),
                                      test.x, test.y, local_epochs=5,
                                      batch_size=32, local_lr=3e-3)
        for scheme in registry.ALL_BASELINES:
            t0 = time.time()
            spec = registry.baseline_spec(scheme, n=n, d=int(theta0.shape[0]),
                                          server_lr=1.0)
            out = FLEngine(ctask, spec).run(shards, theta0, rounds=rounds, seed=0)
            print(_fmt_row(scheme, out) + f"  [{time.time()-t0:.0f}s]", flush=True)
            jax.clear_caches()


def table_cfl(fast: bool):
    """BiCompFL-GR-CFL vs sign-EF baselines (paper Section 4)."""
    rounds = 6 if fast else 10
    print(f"\n== table_cfl (conventional FL, stochastic sign + MRC) ==")
    k, shards, test = _setup(iid=True)
    net = make_mlp(in_dim=100, widths=(256,))
    task, theta0 = make_cfl_task(net, jax.random.fold_in(k, 3), test.x, test.y,
                                 local_epochs=5, batch_size=32, local_lr=3e-3)
    out = FLEngine(task, registry.cfl_spec(server_lr=1.0)).run(
        shards, theta0, rounds=rounds, seed=0)
    print(_fmt_row("BiCompFL-GR-CFL", out))
    n, d = int(shards.x.shape[0]), int(theta0.shape[0])
    for scheme in ("doublesqueeze", "memsgd", "fedavg"):
        spec = registry.baseline_spec(scheme, n=n, d=d, server_lr=1.0)
        out = FLEngine(task, spec).run(shards, theta0, rounds=rounds, seed=0)
        print(_fmt_row(scheme, out))


def ablation_ndl(fast: bool):
    rounds = 4 if fast else 6
    print("\n== ablation: n_DL (paper J.3, BiCompFL-PR) ==")
    k, shards, test = _setup(iid=True)
    task = _mask_task(k, test)
    for n_dl in (2, 5, 10):
        spec = registry.bicompfl_spec("PR", allocation=FixedAllocation(128),
                                      n_is=64, n_dl=n_dl)
        out = FLEngine(task, spec).run(shards, rounds=rounds, seed=0)
        print(_fmt_row(f"PR n_DL={n_dl}", out), flush=True)
        jax.clear_caches()


def ablation_nis(fast: bool):
    rounds = 4 if fast else 6
    print("\n== ablation: n_IS (paper J.5, BiCompFL-GR) ==")
    k, shards, test = _setup(iid=True)
    task = _mask_task(k, test)
    for n_is in (16, 64, 256):
        spec = registry.bicompfl_spec("GR", allocation=FixedAllocation(128),
                                      n_is=n_is, n_dl=int(shards.x.shape[0]))
        out = FLEngine(task, spec).run(shards, rounds=rounds, seed=0)
        print(_fmt_row(f"GR n_IS={n_is}", out), flush=True)
        jax.clear_caches()


def ablation_block(fast: bool):
    rounds = 4 if fast else 6
    print("\n== ablation: block size d/B (paper J.4, BiCompFL-GR) ==")
    k, shards, test = _setup(iid=True)
    task = _mask_task(k, test)
    for bs in (64, 128, 256):
        spec = registry.bicompfl_spec("GR", allocation=FixedAllocation(bs),
                                      n_is=64, n_dl=int(shards.x.shape[0]))
        out = FLEngine(task, spec).run(shards, rounds=rounds, seed=0)
        print(_fmt_row(f"GR block={bs}", out), flush=True)
        jax.clear_caches()


def ablation_nclients(fast: bool):
    rounds = 4 if fast else 6
    print("\n== ablation: number of clients (paper J.1) ==")
    for n in (4, 8) if fast else (4, 8, 16):
        k, shards, test = _setup(iid=True, n_clients=n)
        task = _mask_task(k, test)
        spec = registry.bicompfl_spec("GR", allocation=FixedAllocation(128),
                                      n_is=64, n_dl=n)
        out = FLEngine(task, spec).run(shards, rounds=rounds, seed=0)
        print(_fmt_row(f"GR n={n}", out), flush=True)
        jax.clear_caches()


def kernel_micro(fast: bool):
    print("\n== kernel microbench: mrc_logw / bernoulli_kl (interpret) vs jnp ==")
    from repro.kernels import ops, ref
    key = jax.random.PRNGKey(0)
    nb, nis, s = (8, 256, 256)
    x = (jax.random.uniform(key, (nb, nis, s)) < 0.5).astype(jnp.float32)
    a = jax.random.normal(jax.random.fold_in(key, 1), (nb, s))
    b = jax.random.normal(jax.random.fold_in(key, 2), (nb, s))

    def bench(f, *args, reps=5):
        out = f(*args)
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(reps):
            out = f(*args)
        jax.block_until_ready(out)
        return (time.time() - t0) / reps * 1e6, out

    t_ref, o_ref = bench(jax.jit(ref.mrc_logw_ref), x, a, b)
    t_pal, o_pal = bench(lambda *z: ops.mrc_logw(*z), x, a, b)
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    print(f"mrc_logw ({nb}x{nis}x{s}):  jnp={t_ref:9.1f}us  "
          f"pallas(interpret)={t_pal:9.1f}us  max_err={err:.2e}")
    q = jax.random.uniform(key, (64, 256), minval=0.05, maxval=0.95)
    p = jax.random.uniform(jax.random.fold_in(key, 3), (64, 256),
                           minval=0.05, maxval=0.95)
    t_ref, o_ref = bench(jax.jit(ref.bernoulli_kl_ref), q, p)
    t_pal, o_pal = bench(lambda *z: ops.bernoulli_kl(*z), q, p)
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    print(f"bernoulli_kl (64x256):  jnp={t_ref:9.1f}us  "
          f"pallas(interpret)={t_pal:9.1f}us  max_err={err:.2e}")
    print("(interpret mode runs the kernel body in Python -- correctness "
          "check; TPU timing requires hardware)")


def wire_audit(fast: bool):
    """Bytes on the wire per scheme (repro.wire bitstream layer).

    Every scheme in the registry matrix runs a short ``wire="audit"`` host
    run: each payload is serialized through the codecs, the decoded values
    drive the trajectory, and the BitMeter is reconciled against the
    stream -- a booked-vs-serialized divergence raises inside ``run``.
    The table's bytes column is the *actual* stream length, not a formula.
    """
    rounds = 2 if fast else 3
    print(f"\n== wire_audit: {rounds} wire-audited host rounds, 4 clients, "
          f"reset_period=2 ==")
    k, shards, test = _setup(iid=True, n_train=240, n_test=120, hw=6)
    task = _mask_task(k, test, hw=6, width=32, local_epochs=1)
    net = make_mlp(in_dim=36, widths=(32,))
    ctask, theta0 = make_cfl_task(net, jax.random.fold_in(k, 3), test.x,
                                  test.y, local_epochs=1, batch_size=40,
                                  local_lr=3e-3)
    n, d = int(shards.x.shape[0]), int(theta0.shape[0])
    print(f"{'scheme':26s} {'bytes':>10s} {'B/round':>9s} {'payload_b':>11s} "
          f"{'framing_b':>10s} {'msgs':>5s} {'bpp':>9s}")
    for name, kind, factory in registry.all_schemes(
            n=n, d=d, n_is=16, block=64, reset_period=2,
            include_adaptive=True):
        t = task if kind == "mask" else ctask
        th0 = None if kind == "mask" else theta0
        out = FLEngine(t, factory()).run(shards, th0, rounds=rounds, seed=0,
                                         eval_every=rounds, mode="host",
                                         wire="audit")
        ws = out["wire"]
        print(f"{name:26s} {ws['stream_bytes']:>10,} "
              f"{ws['stream_bytes'] / rounds:>9,.0f} "
              f"{ws['payload_bits']:>11,} {ws['framing_bits']:>10,} "
              f"{ws['messages']:>5} {out['meter']['bpp']:>9.4f}", flush=True)
        jax.clear_caches()


def roofline(fast: bool):
    print("\n== roofline table (from dry-run artifacts) ==")
    found = False
    for path in ("dryrun_1pod.json", "dryrun_2pod.json"):
        if not os.path.exists(path):
            continue
        found = True
        with open(path) as f:
            rows = json.load(f)
        print(f"\n-- {path} --")
        hdr = (f"{'arch':26s} {'shape':12s} {'stat':5s} {'compute_s':>10s} "
               f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
               f"{'args/dev':>10s} {'MF/HLO':>7s}")
        print(hdr)
        for r in rows:
            if r["status"] == "skip":
                print(f"{r['arch']:26s} {r['shape']:12s} skip   ({r['reason']})")
                continue
            if r["status"] != "ok":
                print(f"{r['arch']:26s} {r['shape']:12s} FAIL   {r.get('error','')[:60]}")
                continue
            rl = r["roofline"]
            chips = 512 if r["multi_pod"] else 256
            mf = r["model_flops_6nd"] / chips / max(rl["flops_per_dev"], 1)
            print(f"{r['arch']:26s} {r['shape']:12s} ok    "
                  f"{rl['compute_s']:10.4f} {rl['memory_s']:10.4f} "
                  f"{rl['collective_s']:10.4f} {rl['dominant']:>10s} "
                  f"{r['memory']['argument_bytes']/2**30:9.2f}G "
                  f"{mf:7.2f}")
    if not found:
        print("(no dryrun_*.json found -- run python -m repro.launch.dryrun --all)")


BENCHES = {
    "table_main": table_main,
    "table_cfl": table_cfl,
    "ablation_ndl": ablation_ndl,
    "ablation_nis": ablation_nis,
    "ablation_block": ablation_block,
    "ablation_nclients": ablation_nclients,
    "kernel_micro": kernel_micro,
    "wire_audit": wire_audit,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    t0 = time.time()
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        print(SEP)
        fn(args.fast)
    print(SEP)
    print(f"total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
