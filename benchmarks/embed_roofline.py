"""Embed the generated roofline markdown tables into EXPERIMENTS.md."""
import io
import re
import sys
from contextlib import redirect_stdout

from benchmarks import roofline_md


def main():
    buf = io.StringIO()
    with redirect_stdout(buf):
        for p in ("dryrun_1pod.json", "dryrun_2pod.json"):
            roofline_md.emit(p)
    tables = buf.getvalue()
    path = "EXPERIMENTS.md"
    text = open(path).read()
    marker = "<!-- ROOFLINE_TABLES -->"
    start = text.index(marker)
    end = text.index("### Reading of the baseline table")
    text = text[:start] + marker + "\n" + tables + "\n" + text[end:]
    open(path, "w").write(text)
    print("embedded roofline tables into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
