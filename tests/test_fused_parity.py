"""Fused-vs-host engine parity: the device-resident ``lax.scan`` path must
reproduce the host round loop **bit-for-bit** -- identical histories
(accuracy floats, cumulative bits), meters, and final ``theta`` /
``theta_hat`` arrays, exact equality with no tolerances.

Covers every registry scheme with a static block plan (all four BiCompFL
variants, BiCompFL-CFL, the seven baselines incl. the CSER/LIEC flush
path), full and partial participation, both cohort RNGs, and non-unit eval
cadence.  Schemes needing the host control plane (adaptive allocation) must
refuse ``mode="fused"`` and silently fall back under ``mode="auto"``.
"""
import jax
import numpy as np
import pytest

from repro.core.blocks import AdaptiveAllocation, FixedAllocation
from repro.fl import registry
from repro.fl.data import make_synthetic, partition_iid
from repro.fl.engine import FLEngine
from repro.fl.nets import make_mlp
from repro.fl.tasks import make_cfl_task, make_mask_task

SCHEMES = registry.all_schemes(n=3, d=1472, n_is=16, block=64, reset_period=2)


@pytest.fixture(scope="module")
def mask_setup():
    k = jax.random.PRNGKey(3)
    train, test = make_synthetic(k, n_train=240, n_test=120, hw=6, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, 3, 80)
    net = make_mlp(in_dim=36, widths=(32,), signed_constant=True)
    task = make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                          local_epochs=1, batch_size=40)
    return task, shards


@pytest.fixture(scope="module")
def cfl_setup():
    k = jax.random.PRNGKey(4)
    train, test = make_synthetic(k, n_train=240, n_test=120, hw=6, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, 3, 80)
    net = make_mlp(in_dim=36, widths=(32,))
    task, theta0 = make_cfl_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                                 local_epochs=2, batch_size=40, local_lr=3e-3)
    assert int(theta0.shape[0]) == 1472  # keep SCHEMES' d in sync
    return task, theta0, shards


def _assert_identical(host, fused):
    assert len(host["history"]) == len(fused["history"])
    for hh, hf in zip(host["history"], fused["history"]):
        for key in hh:
            assert hf[key] == hh[key], (key, hh, hf)
    for key in host["meter"]:
        assert fused["meter"][key] == host["meter"][key], key
    np.testing.assert_array_equal(np.asarray(host["theta"]),
                                  np.asarray(fused["theta"]))
    np.testing.assert_array_equal(np.asarray(host["theta_hat"]),
                                  np.asarray(fused["theta_hat"]))
    np.testing.assert_array_equal(host["active_schedule"],
                                  fused["active_schedule"])
    assert fused["final_acc"] == host["final_acc"]
    assert fused["max_acc"] == host["max_acc"]


def _run_both(task, spec_factory, shards, theta0=None, *, rounds=3, seed=11,
              **kw):
    host = FLEngine(task, spec_factory()).run(
        shards, theta0, rounds=rounds, seed=seed, mode="host", **kw)
    fused = FLEngine(task, spec_factory()).run(
        shards, theta0, rounds=rounds, seed=seed, mode="fused", **kw)
    _assert_identical(host, fused)
    return host


@pytest.mark.parametrize("name,kind,factory", SCHEMES,
                         ids=[s[0] for s in SCHEMES])
def test_fused_matches_host(mask_setup, cfl_setup, name, kind, factory):
    if kind == "mask":
        task, shards = mask_setup
        _run_both(task, factory, shards)
    else:
        task, theta0, shards = cfl_setup
        # reset_period=2 inside 3 rounds exercises the lax.cond flush branch
        _run_both(task, factory, shards, theta0)


@pytest.mark.parametrize("cohort_rng", ["numpy", "jax"])
def test_fused_partial_participation(mask_setup, cohort_rng):
    task, shards = mask_setup
    factory = lambda: registry.bicompfl_spec(
        "PR", allocation=FixedAllocation(64), n_is=16, n_dl=3,
        participation=0.67)
    out = _run_both(task, factory, shards, rounds=3, cohort_rng=cohort_rng)
    assert out["active_schedule"].shape == (3, 2)  # 0.67 of 3 -> 2 active


def test_fused_eval_cadence(mask_setup):
    """lax.cond-gated eval: only scheduled rounds (plus the last) appear."""
    task, shards = mask_setup
    factory = lambda: registry.bicompfl_spec(
        "GR", allocation=FixedAllocation(64), n_is=16, n_dl=3)
    out = _run_both(task, factory, shards, rounds=3, eval_every=2)
    assert [h["round"] for h in out["history"]] == [2, 3]


def test_adaptive_allocation_falls_back_to_host(mask_setup):
    task, shards = mask_setup
    spec = registry.bicompfl_spec("GR", allocation=AdaptiveAllocation(n_is=16),
                                  n_is=16, n_dl=3)
    engine = FLEngine(task, spec)
    assert not engine.fused_supported()
    with pytest.raises(ValueError):
        engine.run(shards, rounds=2, seed=1, mode="fused")
    auto = engine.run(shards, rounds=2, seed=11, mode="auto")
    host = engine.run(shards, rounds=2, seed=11, mode="host")
    _assert_identical(host, auto)


def test_fixed_allocation_auto_uses_fused(mask_setup):
    task, shards = mask_setup
    engine = FLEngine(task, registry.bicompfl_spec(
        "GR", allocation=FixedAllocation(64), n_is=16, n_dl=3))
    assert engine.fused_supported()
