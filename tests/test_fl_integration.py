"""End-to-end FL integration: BiCompFL variants train, bits are booked
per the paper's accounting, orderings from the paper hold."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocks import AdaptiveAvgAllocation, FixedAllocation
from repro.fl.data import make_synthetic, partition_dirichlet, partition_iid
from repro.fl.federator import BiCompFLConfig, CFLConfig, run_bicompfl, run_bicompfl_cfl
from repro.fl.nets import make_cnn, make_mlp
from repro.fl.tasks import make_cfl_task, make_mask_task


@pytest.fixture(scope="module")
def small_setup():
    k = jax.random.PRNGKey(0)
    train, test = make_synthetic(k, n_train=800, n_test=300, hw=8, noise=0.4)
    shards = partition_iid(jax.random.fold_in(k, 1), train, 4, 200)
    net = make_mlp(in_dim=64, widths=(96,), signed_constant=True)
    task = make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                          local_epochs=2)
    return task, shards


@pytest.mark.parametrize("variant", ["GR", "GR-Reconst", "PR", "PR-SplitDL"])
def test_variants_run_and_learn(small_setup, variant):
    task, shards = small_setup
    cfg = BiCompFLConfig(variant=variant, rounds=4, n_is=32,
                         allocation=FixedAllocation(128))
    out = run_bicompfl(task, shards, cfg)
    assert np.isfinite(out["final_acc"])
    # GR/PR learn fast; the Reconst/SplitDL ablations carry extra MRC noise.
    # PR lands at 0.393 under these tiny settings (identical in the seed
    # loop -- see tests/test_engine_parity.py), so its floor is 0.35.
    floor = 0.4 if variant == "GR" else 0.35 if variant == "PR" else 0.25
    assert out["max_acc"] > floor, out["max_acc"]
    assert out["meter"]["bpp"] > 0


def test_gr_uplink_bpp_matches_formula(small_setup):
    """GR-Fixed: uplink bpp/round == n_blocks*log2(n_is) / d (paper Table 5)."""
    task, shards = small_setup
    n, n_is, bs = 4, 32, 128
    cfg = BiCompFLConfig(variant="GR", rounds=2, n_is=n_is,
                         allocation=FixedAllocation(bs))
    out = run_bicompfl(task, shards, cfg)
    d = task.d
    n_blocks = -(-d // bs)
    expect_ul = n_blocks * math.log2(n_is) / d           # per client per round
    assert abs(out["meter"]["uplink_bpp"] - expect_ul) < 1e-6
    # GR downlink: relay (n-1) clients' indices to each client
    expect_dl = (n - 1) * n_blocks * math.log2(n_is) / d
    assert abs(out["meter"]["downlink_bpp"] - expect_dl) < 1e-6


def test_splitdl_downlink_cheaper(small_setup):
    task, shards = small_setup
    base = BiCompFLConfig(variant="PR", rounds=2, n_is=32,
                          allocation=FixedAllocation(128))
    split = BiCompFLConfig(variant="PR-SplitDL", rounds=2, n_is=32,
                           allocation=FixedAllocation(128))
    out_b = run_bicompfl(task, shards, base)
    out_s = run_bicompfl(task, shards, split)
    assert out_s["meter"]["downlink_bpp"] < out_b["meter"]["downlink_bpp"] / 2


def test_broadcast_bpp_only_helps_gr(small_setup):
    """bpp(BC) divides the GR downlink by n; PR cannot profit (paper App. I)."""
    task, shards = small_setup
    gr = run_bicompfl(task, shards, BiCompFLConfig(variant="GR", rounds=2, n_is=32))
    pr = run_bicompfl(task, shards, BiCompFLConfig(variant="PR", rounds=2, n_is=32))
    assert gr["meter"]["bpp_bc"] < gr["meter"]["bpp"]
    assert abs(pr["meter"]["bpp_bc"] - pr["meter"]["bpp"]) < 1e-9


def test_adaptive_avg_allocation_runs(small_setup):
    task, shards = small_setup
    cfg = BiCompFLConfig(variant="GR", rounds=3, n_is=32,
                         allocation=AdaptiveAvgAllocation(min_block=64,
                                                          max_block=512))
    out = run_bicompfl(task, shards, cfg)
    assert np.isfinite(out["final_acc"])


def test_noniid_dirichlet_partition_runs(small_setup):
    k = jax.random.PRNGKey(5)
    train, test = make_synthetic(k, n_train=800, n_test=200, hw=8, noise=0.6)
    shards = partition_dirichlet(jax.random.fold_in(k, 1), train, 4, 200, alpha=0.1)
    net = make_mlp(in_dim=64, widths=(64,), signed_constant=True)
    task = make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                          local_epochs=1)
    out = run_bicompfl(task, shards, BiCompFLConfig(variant="GR", rounds=3, n_is=32))
    assert np.isfinite(out["final_acc"])


def test_cfl_stochastic_sign(small_setup):
    """BiCompFL-GR-CFL on a conventional-FL task: loss-bearing direction."""
    k = jax.random.PRNGKey(7)
    train, test = make_synthetic(k, n_train=800, n_test=200, hw=8, noise=0.6)
    shards = partition_iid(jax.random.fold_in(k, 1), train, 4, 200)
    net = make_mlp(in_dim=64, widths=(64,))
    task, theta0 = make_cfl_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                                 local_epochs=5, batch_size=32, local_lr=3e-3)
    out = run_bicompfl_cfl(task, theta0, shards,
                           CFLConfig(rounds=4, server_lr=1.0))
    assert np.isfinite(out["final_acc"])
    assert out["max_acc"] > 0.5
    # bitrate: log2(n_is)/block bits per param per direction (order check)
    assert out["meter"]["uplink_bpp"] < 1.0


def test_gr_all_clients_synchronized(small_setup):
    """GR: every client ends each round with the identical estimate."""
    task, shards = small_setup
    out = run_bicompfl(task, shards, BiCompFLConfig(variant="GR", rounds=2, n_is=16))
    th = np.asarray(out["theta_hat"])
    for i in range(1, th.shape[0]):
        np.testing.assert_array_equal(th[0], th[i])


def test_pr_partial_participation(small_setup):
    """PR with 50% participation per round: runs, learns, bills only the
    active cohort; GR refuses (incompatible with global randomness)."""
    task, shards = small_setup
    cfg = BiCompFLConfig(variant="PR", rounds=4, n_is=32, participation=0.5,
                         allocation=FixedAllocation(128))
    out = run_bicompfl(task, shards, cfg)
    assert np.isfinite(out["final_acc"])
    full = run_bicompfl(task, shards,
                        BiCompFLConfig(variant="PR", rounds=4, n_is=32,
                                       allocation=FixedAllocation(128)))
    assert out["meter"]["bpp"] < full["meter"]["bpp"] * 0.75
    with pytest.raises(ValueError):
        run_bicompfl(task, shards,
                     BiCompFLConfig(variant="GR", rounds=1, participation=0.5))


def test_pr_clients_diverge(small_setup):
    """PR: without shared candidates the clients' estimates differ."""
    task, shards = small_setup
    out = run_bicompfl(task, shards, BiCompFLConfig(variant="PR", rounds=2, n_is=16))
    th = np.asarray(out["theta_hat"])
    assert not np.array_equal(th[0], th[1])
