"""BiCompFL federator entry points (paper Algorithms 1 & 2 + variants).

Implemented variants (cfg.variant):

* ``GR``          -- Alg. 1: global shared randomness; the federator *relays*
                     the clients' MRC indices, every client reconstructs the
                     identical global model (no extra compression noise).
* ``GR-Reconst``  -- the suboptimal ablation: the federator reconstructs the
                     global model and re-transmits it via a second MRC round
                     (common candidates -> all clients equal estimates).
* ``PR``          -- Alg. 2: private shared randomness only; per-client MRC
                     on the downlink; clients hold distinct estimates.
* ``PR-SplitDL``  -- PR, but the downlink sends each client only a disjoint
                     1/n slice of the blocks (downlink cost / n).

The uplink/downlink priors are the clients' latest global-model estimates
(theta_hat), exactly as the paper settles on (lambda = 1).

These functions are thin, backwards-compatible wrappers: each builds an
:class:`~repro.fl.engine.EngineSpec` from the scheme registry and runs the
shared :class:`~repro.fl.engine.FLEngine` round loop.  New scenarios should
compose channels directly (see DESIGN.md) rather than grow these configs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax

from repro.core.blocks import FixedAllocation
from . import registry
from .channels import from_blocks, to_blocks  # noqa: F401  (back-compat)
from .data import Dataset
from .engine import FLEngine


@dataclass
class BiCompFLConfig:
    variant: str = "GR"          # GR | GR-Reconst | PR | PR-SplitDL
    allocation: Any = field(default_factory=lambda: FixedAllocation(256))
    n_is: int = 256
    n_ul: int = 1
    n_dl: Optional[int] = None   # default: n_clients * n_ul (paper)
    rounds: int = 30
    seed: int = 0
    eval_every: int = 1
    chunk: int = 16              # MRC encode block-chunk (memory knob)
    logw_fn: Any = None          # optionally the Pallas kernel closure
    participation: float = 1.0   # fraction of clients per round; < 1 only
                                 # valid for PR variants (the paper notes
                                 # partial participation is incompatible
                                 # with global shared randomness)


def run_bicompfl(task, shards: Dataset, cfg: BiCompFLConfig) -> Dict[str, Any]:
    """Run probabilistic-mask BiCompFL; returns history + bit accounting."""
    n = int(shards.x.shape[0])
    n_dl = cfg.n_dl if cfg.n_dl is not None else n * cfg.n_ul
    spec = registry.bicompfl_spec(
        cfg.variant, allocation=cfg.allocation, n_is=cfg.n_is, n_ul=cfg.n_ul,
        n_dl=n_dl, chunk=cfg.chunk, logw_fn=cfg.logw_fn,
        participation=cfg.participation)
    return FLEngine(task, spec).run(shards, rounds=cfg.rounds, seed=cfg.seed,
                                    eval_every=cfg.eval_every)


@dataclass
class CFLConfig:
    # CFL compression is near-element-wise (paper Sec. 4): a *small* block
    # keeps per-block d_KL(q || 1/2) within the log(n_is) MRC budget --
    # stochastic-sign posteriors sit far from the uninformative prior.
    n_is: int = 256
    n_ul: int = 1
    block_size: int = 16
    rounds: int = 30
    server_lr: float = 1.0
    seed: int = 0
    eval_every: int = 1
    chunk: int = 16
    temperature: str = "auto"    # K: "auto" => mean |delta| per client
    logw_fn: Any = None


def run_bicompfl_cfl(task, theta0: jax.Array, shards: Dataset,
                     cfg: CFLConfig) -> Dict[str, Any]:
    """BiCompFL-GR applied to conventional FL with stochastic SignSGD.

    Clients quantize their local delta with q = sigmoid(delta / K), convey
    samples via MRC against the uninformative prior p = 1/2, the federator
    averages the reconstructed directions (2*q_hat - 1) and steps; indices
    are relayed on the downlink (global randomness) so the clients track the
    identical global model.
    """
    spec = registry.cfl_spec(n_is=cfg.n_is, n_ul=cfg.n_ul,
                             block_size=cfg.block_size,
                             server_lr=cfg.server_lr, chunk=cfg.chunk,
                             logw_fn=cfg.logw_fn)
    return FLEngine(task, spec).run(shards, theta0, rounds=cfg.rounds,
                                    seed=cfg.seed, eval_every=cfg.eval_every)
