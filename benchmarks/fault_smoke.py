"""CI fault-matrix smoke: every uplink channel family under a hostile
``FaultPlan``, with a kill-and-resume leg, emitting the per-run fault
event logs as a CI artifact.

One scheme per family (``registry.fault_matrix``: MRC index streams,
quantized-MRC deltas, sign-EF, top-k EF, dense) runs three legs:

1. **faulted run** -- dropouts + stragglers + frame corruption at the
   DESIGN.md §8 smoke rates (drop 0.3); the plan must actually bite
   (``faulty_rounds > 0``) and the booked ``retransmit_bits`` must equal
   the fault report's total;
2. **host/fused agreement** -- the same seed's faulted run on the other
   engine path must produce the identical fault report and final model;
3. **kill + resume** -- the run is checkpointed, every checkpoint after
   round ``rounds//2`` is deleted (the "crash"), and the resumed run
   must be bit-identical to the uninterrupted one.

The collected ``out["faults"]`` reports land in ``fault_events.json``
(uploaded by CI), so a fault-semantics regression shows up as an artifact
diff as well as a red line.

Run:  PYTHONPATH=src python -m benchmarks.fault_smoke [--rounds N]
      [--out fault_events.json]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import tempfile

import jax
import numpy as np

from repro.fl import registry
from repro.fl.data import make_synthetic, partition_iid
from repro.fl.engine import FLEngine
from repro.fl.faults import FaultPlan
from repro.fl.nets import make_mlp
from repro.fl.tasks import make_cfl_task, make_mask_task

N_CLIENTS = 4
PLAN = FaultPlan(drop_rate=0.3, straggler_rate=0.1, corrupt_rate=0.2,
                 seed=1)


def build_setup():
    k = jax.random.PRNGKey(0)
    train, test = make_synthetic(k, n_train=240, n_test=120, hw=6, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, N_CLIENTS, 60)
    net = make_mlp(in_dim=36, widths=(32,), signed_constant=True)
    task = make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                          local_epochs=1, batch_size=40)
    cnet = make_mlp(in_dim=36, widths=(32,))
    ctask, theta0 = make_cfl_task(cnet, jax.random.fold_in(k, 3), test.x,
                                  test.y, local_epochs=1, batch_size=40,
                                  local_lr=3e-3)
    return task, ctask, theta0, shards


def assert_identical(a, b, label):
    assert len(a["history"]) == len(b["history"]), label
    for ha, hb in zip(a["history"], b["history"]):
        assert ha == hb, (label, ha, hb)
    assert a["meter"] == b["meter"], label
    np.testing.assert_array_equal(np.asarray(a["theta"]),
                                  np.asarray(b["theta"]), err_msg=label)
    np.testing.assert_array_equal(np.asarray(a["theta_hat"]),
                                  np.asarray(b["theta_hat"]), err_msg=label)


def smoke_scheme(name, task, factory, shards, theta0, *, rounds):
    kw = dict(rounds=rounds, seed=7, eval_every=max(rounds // 4, 1),
              faults=PLAN)

    host = FLEngine(task, factory()).run(shards, theta0, mode="host", **kw)
    rep = host["faults"]
    assert rep["summary"]["faulty_rounds"] > 0, \
        f"{name}: the fault plan never bit -- smoke proves nothing"
    assert host["meter"]["retransmit_bits"] == \
        rep["summary"]["retransmit_bits_total"], name

    fused = FLEngine(task, factory()).run(shards, theta0, mode="fused", **kw)
    assert_identical(host, fused, f"{name}: host vs fused under faults")
    assert fused["faults"] == rep, name

    # kill + resume: drop every checkpoint after the midpoint, resume, and
    # demand the bit-identical trajectory
    with tempfile.TemporaryDirectory() as ckdir:
        FLEngine(task, factory()).run(shards, theta0, mode="host",
                                      checkpoint_dir=ckdir,
                                      checkpoint_every=max(rounds // 2, 1),
                                      **kw)
        keep = max(rounds // 2, 1)
        for p in glob.glob(os.path.join(ckdir, "ckpt_*.repro")):
            if int(os.path.basename(p)[5:13]) > keep:
                os.remove(p)
        resumed = FLEngine(task, factory()).run(shards, theta0, mode="host",
                                                resume_from=ckdir, **kw)
    assert_identical(host, resumed, f"{name}: killed-at-{keep} resume")

    s = rep["summary"]
    print(f"{name:16s} faulty_rounds={s['faulty_rounds']}/{rounds}  "
          f"dropped={s['dropped_total']} stragglers={s['stragglers_total']} "
          f"lost={s['lost_uplink_total']}+{s['lost_downlink_total']}  "
          f"retransmits={s['retransmits_total']} "
          f"({s['retransmit_bits_total']:,.0f} bits)  "
          f"resume@{keep} ok", flush=True)
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--out", default="fault_events.json")
    args = ap.parse_args()

    task, ctask, theta0, shards = build_setup()
    d = int(theta0.shape[0])
    matrix = registry.fault_matrix(n=N_CLIENTS, d=d, n_is=16, block=16,
                                   reset_period=2)
    print(f"== fault_smoke: {args.rounds} rounds, {N_CLIENTS} clients, "
          f"d={d}, plan={PLAN} ==")

    reports = {}
    for name, kind, factory in matrix:
        t, th0 = (task, None) if kind == "mask" else (ctask, theta0)
        reports[name] = smoke_scheme(name, t, factory, shards, th0,
                                     rounds=args.rounds)
        jax.clear_caches()

    with open(args.out, "w") as f:
        json.dump({"plan": reports[matrix[0][0]]["plan"],
                   "schemes": reports}, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
