"""Pallas TPU kernel: MRC importance log-weights as an MXU matvec.

The per-block MRC weight evaluation

    logW[i] = sum_e  x_{ie} * a_e + b_e          (i in [n_IS])

is the compute hot-spot of BiCompFL encoding: every round, every client
evaluates it for every block (d * n_IS multiply-adds total).  Refactored as

    logW = X @ a + sum(b)

it is a (n_IS x S) x (S,) product -- ideal for the 128x128 systolic MXU once
tiled.  TPU adaptation (vs. the paper's GPU runs): candidates X live in HBM
as (NB, NIS, S); we stream (TI=128, TS=128) tiles through VMEM, accumulate
partial dot products in the f32 output block, and fold the offset term
sum_s b[nb, s] in on the first S-tile.  Grid: (NB, NIS/TI, S/TS); the output
BlockSpec maps all S-tiles of one (nb, i-tile) to the same VMEM block, so the
accumulation is carried in VMEM without HBM round-trips.

VMEM working set per step: 128*128*4 (X) + 2*128*4 (a, b) + 128*4 (out)
~ 66 KiB  <<  16 MiB VMEM; the MXU matvec dims are 128-aligned by padding in
``ops.mrc_logw``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_I = 128  # candidate-row tile (MXU sublane dim)
TILE_S = 128  # block-entry tile (MXU lane dim)


def _mrc_logw_kernel(x_ref, a_ref, b_ref, o_ref):
    """One (nb, i_tile, s_tile) grid step."""
    s = pl.program_id(2)

    x = x_ref[0]          # (TILE_I, TILE_S) candidate bits
    a = a_ref[0]          # (TILE_S,)
    b = b_ref[0]          # (TILE_S,)

    # Partial matvec on the MXU; f32 accumulation.
    part = jnp.dot(x, a[:, None], preferred_element_type=jnp.float32)[:, 0]

    @pl.when(s == 0)
    def _init():
        o_ref[0] = part + jnp.sum(b)

    @pl.when(s != 0)
    def _acc():
        o_ref[0] = o_ref[0] + part + jnp.sum(b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mrc_logw_pallas(x: jax.Array, a: jax.Array, b: jax.Array, *, interpret: bool = True):
    """logW = X @ a + sum(b) for 128-aligned shapes.

    x: (NB, NIS, S) float32 {0,1};  a, b: (NB, S);  returns (NB, NIS).
    Shapes must satisfy NIS % TILE_I == 0 and S % TILE_S == 0 (use
    ``ops.mrc_logw`` for the padded general-shape entry point).
    """
    nb, nis, s = x.shape
    if nis % TILE_I != 0 or s % TILE_S != 0:
        raise ValueError(
            f"mrc_logw_pallas needs NIS % {TILE_I} == 0 and S % {TILE_S} "
            f"== 0, got NIS={nis}, S={s} (use ops.mrc_logw for the padded "
            "general-shape entry point)")
    grid = (nb, nis // TILE_I, s // TILE_S)
    return pl.pallas_call(
        _mrc_logw_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TILE_I, TILE_S), lambda b_, i, s_: (b_, i, s_)),
            pl.BlockSpec((1, TILE_S), lambda b_, i, s_: (b_, s_)),
            pl.BlockSpec((1, TILE_S), lambda b_, i, s_: (b_, s_)),
        ],
        out_specs=pl.BlockSpec((1, TILE_I), lambda b_, i, s_: (b_, i)),
        out_shape=jax.ShapeDtypeStruct((nb, nis), jnp.float32),
        interpret=interpret,
    )(x, a, b)
