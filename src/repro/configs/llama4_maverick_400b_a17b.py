"""Llama-4 Maverick 400B-A17B: MoE 128e top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", arch_type="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    n_experts=128, top_k=1, moe_d_ff=8192, shared_experts=1,
    moe_every=2,  # Maverick interleaves dense::MoE 1:1
    long_context_window=8192,  # chunked-local attention stands in for long ctx
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family card)",
)
