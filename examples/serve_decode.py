"""Batched serving example: prefill-free decode with a KV cache.

    PYTHONPATH=src python examples/serve_decode.py [--arch qwen3-1.7b]

Instantiates the *reduced* variant of an assigned architecture (CPU-sized)
and serves a batch of randomly tokenized requests through the same
``serve_step`` the multi-pod dry-run lowers at full scale.
"""
import argparse
import time

import numpy as np

import repro.configs as C
from repro.launch.serve import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list(C.ALIASES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = C.get(args.arch).reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    print(f"serving reduced {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab}")

    server = Server(cfg, max_batch=args.batch, max_seq=128)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=rng.integers(4, 12)),
                    max_new_tokens=args.new_tokens, temperature=0.8)
            for _ in range(args.batch)]

    t0 = time.time()
    outs = server.generate(reqs)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"req {i}: prompt_len={len(reqs[i].prompt)}  -> {o[:12]}...")
    print(f"{total_new} tokens in {dt:.1f}s  ({total_new/dt:.1f} tok/s, "
          f"CPU, reduced config)")


if __name__ == "__main__":
    main()
