"""Assigned-architecture registry.

``get(arch_id)`` returns the exact ArchConfig from the assignment table;
``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of one of the four canonical input shapes (no allocation --
the dry-run path), together with the step kind they drive.

Shapes:
    train_4k     seq 4,096    global_batch 256   (train_step)
    prefill_32k  seq 32,768   global_batch  32   (prefill forward)
    decode_32k   seq 32,768   global_batch 128   (serve_step, KV cache)
    long_500k    seq 524,288  global_batch   1   (serve_step, sub-quadratic)
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

ARCH_IDS = [
    "kimi_k2_1t_a32b",
    "deepseek_coder_33b",
    "rwkv6_1p6b",
    "hubert_xlarge",
    "qwen3_14b",
    "llama4_maverick_400b_a17b",
    "qwen3_1p7b",
    "minitron_8b",
    "qwen2_vl_72b",
    "jamba_v0p1_52b",
]

# public --arch ids (hyphenated) -> module names
ALIASES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen3-14b": "qwen3_14b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen3-1.7b": "qwen3_1p7b",
    "minitron-8b": "minitron_8b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "jamba-v0.1-52b": "jamba_v0p1_52b",
}

SHAPES: Dict[str, Dict] = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def get(arch_id: str) -> ArchConfig:
    mod_name = ALIASES.get(arch_id, arch_id)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get(a) for a in ARCH_IDS}


def shape_supported(cfg: ArchConfig, shape: str) -> Optional[str]:
    """None if (cfg, shape) is runnable; otherwise the skip reason."""
    info = SHAPES[shape]
    if info["kind"] == "decode":
        if not cfg.supports_decode:
            return "encoder-only: no decode step"
        if shape == "long_500k" and not cfg.supports_long_context:
            return "full quadratic attention: 500k decode cache intractable"
    return None


def for_shape(cfg: ArchConfig, shape: str) -> ArchConfig:
    """Shape-adapted config (e.g. the SWA long-context variant)."""
    import dataclasses
    if shape == "long_500k" and cfg.long_context_window and not cfg.sliding_window:
        return dataclasses.replace(cfg, sliding_window=cfg.long_context_window)
    return cfg


def input_specs(cfg: ArchConfig, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for each input of (cfg, shape)."""
    info = SHAPES[shape]
    s, b = info["seq"], info["batch"]
    f = jax.ShapeDtypeStruct
    if info["kind"] == "decode":
        return {"tokens": f((b, 1), jnp.int32),
                "pos": f((), jnp.int32)}
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.embed_inputs:
        batch["tokens"] = f((b, s), jnp.int32)
    else:
        batch["inputs"] = f((b, s, cfg.d_model), jnp.float32)
    if cfg.vlm_image_tokens:
        batch["image_embeds"] = f((b, cfg.vlm_image_tokens, cfg.d_model), jnp.float32)
        if cfg.rope_kind == "mrope":
            batch["positions"] = f((b, s, 3), jnp.int32)
    if info["kind"] == "train":
        batch["labels"] = f((b, s), jnp.int32)
    return batch
