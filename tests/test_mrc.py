"""MRC codec: roundtrip identity, estimator behaviour, property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import mrc
from repro.core.bernoulli import bern_kl, clip01, inv_sigmoid, log_ratio_coeffs, sigmoid

KEY = jax.random.PRNGKey(0)


def _qp(key, b=6, s=32, spread=0.1):
    q = jax.random.uniform(jax.random.fold_in(key, 1), (b, s), minval=0.15, maxval=0.85)
    p = jnp.clip(q + spread * jax.random.normal(jax.random.fold_in(key, 2), (b, s)),
                 0.05, 0.95)
    return q, p


class TestFixedCodec:
    def test_roundtrip_identity(self):
        q, p = _qp(KEY)
        res = mrc.encode_fixed(KEY, jax.random.fold_in(KEY, 3), q, p, n_is=32)
        dec = mrc.decode_fixed(KEY, res.indices, p, n_is=32)
        np.testing.assert_array_equal(np.asarray(res.sample), np.asarray(dec))

    def test_indices_in_range(self):
        q, p = _qp(KEY)
        res = mrc.encode_fixed(KEY, jax.random.fold_in(KEY, 3), q, p, n_is=16)
        idx = np.asarray(res.indices)
        assert idx.min() >= 0 and idx.max() < 16

    def test_sample_is_binary(self):
        q, p = _qp(KEY)
        res = mrc.encode_fixed(KEY, jax.random.fold_in(KEY, 3), q, p, n_is=16)
        s = np.asarray(res.sample)
        assert set(np.unique(s)).issubset({0.0, 1.0})

    def test_zero_kl_is_exact_prior_sample(self):
        """q == p => W uniform => the sample is a prior draw (still valid)."""
        p = jnp.full((4, 16), 0.5)
        res = mrc.encode_fixed(KEY, jax.random.fold_in(KEY, 3), p, p, n_is=8)
        assert res.sample.shape == (4, 16)

    def test_estimator_improves_with_nis(self):
        """Mean-sample estimate approaches q as n_is grows (Chatterjee-Diaconis)."""
        q, p = _qp(jax.random.fold_in(KEY, 9), b=4, s=64, spread=0.05)
        errs = []
        for n_is in (4, 64, 1024):
            _, qhat = mrc.transmit_fixed(
                jax.random.fold_in(KEY, n_is), jax.random.fold_in(KEY, n_is + 1),
                q, p, n_is=n_is, n_samples=256)
            errs.append(float(jnp.mean(jnp.abs(qhat - q))))
        assert errs[2] < errs[0], errs

    def test_many_samples_concentrate(self):
        q, p = _qp(jax.random.fold_in(KEY, 11), b=4, s=32, spread=0.02)
        _, qhat = mrc.transmit_fixed(KEY, jax.random.fold_in(KEY, 1), q, p,
                                     n_is=256, n_samples=512)
        assert float(jnp.mean(jnp.abs(qhat - q))) < 0.1

    def test_chunking_invariance(self):
        """Same indices regardless of the encode chunk size."""
        q, p = _qp(KEY, b=10)
        r1 = mrc.encode_fixed(KEY, jax.random.fold_in(KEY, 3), q, p, n_is=16, chunk=2)
        r2 = mrc.encode_fixed(KEY, jax.random.fold_in(KEY, 3), q, p, n_is=16, chunk=10)
        np.testing.assert_array_equal(np.asarray(r1.indices), np.asarray(r2.indices))

    def test_pallas_logw_path_matches_default(self):
        from repro.kernels.ops import mrc_logw_fn
        q, p = _qp(KEY, b=5, s=48)
        r1 = mrc.encode_fixed(KEY, jax.random.fold_in(KEY, 3), q, p, n_is=32)
        r2 = mrc.encode_fixed(KEY, jax.random.fold_in(KEY, 3), q, p, n_is=32,
                              logw_fn=mrc_logw_fn())
        np.testing.assert_array_equal(np.asarray(r1.indices), np.asarray(r2.indices))


class TestSegmentCodec:
    def test_roundtrip(self):
        d, n_seg = 64, 4
        q = jax.random.uniform(KEY, (d,), minval=0.2, maxval=0.8)
        p = jnp.clip(q + 0.05, 0.05, 0.95)
        seg = jnp.repeat(jnp.arange(n_seg), d // n_seg)
        res = mrc.encode_segments(KEY, jax.random.fold_in(KEY, 3), q, p, seg,
                                  n_is=16, n_seg=n_seg)
        dec = mrc.decode_segments(KEY, res.indices, p, seg, n_is=16)
        np.testing.assert_array_equal(np.asarray(res.sample), np.asarray(dec))

    def test_rejects_permuted_seg_ids(self):
        """The wire plan header is run-length coded, so a permuted seg_ids
        would silently round-trip to a different segmentation: the codec
        boundary must refuse it."""
        d, n_seg = 16, 4
        q = jax.random.uniform(KEY, (d,), minval=0.2, maxval=0.8)
        p = jnp.clip(q + 0.05, 0.05, 0.95)
        good = jnp.repeat(jnp.arange(n_seg), d // n_seg)
        permuted = good[::-1]
        with pytest.raises(ValueError, match="non-decreasing"):
            mrc.encode_segments(KEY, jax.random.fold_in(KEY, 3), q, p,
                                permuted, n_is=8, n_seg=n_seg)
        with pytest.raises(ValueError, match="non-decreasing"):
            mrc.decode_segments(KEY, jnp.zeros((n_seg,), jnp.int32), p,
                                permuted, n_is=8)
        with pytest.raises(ValueError, match="non-decreasing"):
            mrc.encode_segments(KEY, jax.random.fold_in(KEY, 3), q, p,
                                good + 1, n_is=8, n_seg=n_seg + 1)

    def test_matches_fixed_when_blocks_equal(self):
        """Uniform segments == fixed blocks of the same size (same estimate
        family; indices differ by key layout, so compare statistically)."""
        d, bs = 128, 32
        q = jax.random.uniform(KEY, (d,), minval=0.3, maxval=0.7)
        p = jnp.full((d,), 0.5)
        seg = jnp.repeat(jnp.arange(d // bs), bs)
        _, qs = mrc.transmit_segments(KEY, jax.random.fold_in(KEY, 1), q, p, seg,
                                      n_is=64, n_seg=d // bs, n_samples=128)
        _, qf = mrc.transmit_fixed(KEY, jax.random.fold_in(KEY, 1),
                                   q.reshape(-1, bs), p.reshape(-1, bs),
                                   n_is=64, n_samples=128)
        assert abs(float(jnp.mean(qs) - jnp.mean(qf))) < 0.05


class TestBernoulliUtils:
    @given(st.floats(0.01, 0.99), st.floats(0.01, 0.99))
    @settings(max_examples=50, deadline=None)
    def test_kl_nonnegative(self, q, p):
        kl = float(bern_kl(jnp.float32(q), jnp.float32(p)))
        assert kl >= -1e-6

    @given(st.floats(0.01, 0.99))
    @settings(max_examples=30, deadline=None)
    def test_kl_zero_iff_equal(self, q):
        assert float(bern_kl(jnp.float32(q), jnp.float32(q))) < 1e-9

    @given(st.floats(0.05, 0.95), st.floats(0.05, 0.95))
    @settings(max_examples=50, deadline=None)
    def test_log_ratio_coeffs_consistent(self, q, p):
        """a*x + b must equal log(Q(x)/P(x)) for x in {0, 1}."""
        a, b = log_ratio_coeffs(jnp.float32(q), jnp.float32(p))
        lr1 = np.log(q / p)
        lr0 = np.log((1 - q) / (1 - p))
        assert abs(float(a + b) - lr1) < 1e-4
        assert abs(float(b) - lr0) < 1e-4

    @given(st.floats(0.01, 0.99))
    @settings(max_examples=30, deadline=None)
    def test_sigmoid_inverse(self, t):
        assert abs(float(sigmoid(inv_sigmoid(jnp.float32(t)))) - t) < 1e-4

    def test_clip01_bounds(self):
        x = jnp.array([-1.0, 0.0, 0.5, 1.0, 2.0])
        c = clip01(x)
        assert float(c.min()) > 0.0 and float(c.max()) < 1.0


class TestSharedRandomness:
    def test_same_key_same_candidates(self):
        """Encoder and decoder derive identical candidates: decode of the
        transmitted index reproduces the encoder's selected sample exactly --
        the operational meaning of 'shared randomness'."""
        q, p = _qp(KEY)
        for t in range(3):
            kt = mrc.round_key(KEY, t)
            res = mrc.encode_fixed(kt, jax.random.fold_in(kt, 1), q, p, n_is=32)
            dec = mrc.decode_fixed(kt, res.indices, p, n_is=32)
            np.testing.assert_array_equal(np.asarray(res.sample), np.asarray(dec))

    def test_client_keys_distinct(self):
        k1 = mrc.client_key(KEY, 1)
        k2 = mrc.client_key(KEY, 2)
        assert not np.array_equal(np.asarray(k1), np.asarray(k2))
