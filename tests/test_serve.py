"""Serving loop: batched generation against the reduced configs."""
import numpy as np
import pytest

import repro.configs as C
from repro.launch.serve import Request, Server


def test_generate_batch_shapes():
    cfg = C.get("qwen3-1.7b").reduced()
    server = Server(cfg, max_batch=3, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=5), max_new_tokens=4),
            Request(prompt=rng.integers(0, cfg.vocab, size=8), max_new_tokens=6),
            Request(prompt=rng.integers(0, cfg.vocab, size=3), max_new_tokens=4)]
    outs = server.generate(reqs)
    assert [len(o) for o in outs] == [4, 6, 4]
    for o in outs:
        assert o.dtype == np.int32
        assert (o >= 0).all() and (o < cfg.vocab).all()


def test_greedy_deterministic():
    cfg = C.get("qwen3-1.7b").reduced()
    server = Server(cfg, max_batch=1, max_seq=32)
    req = [Request(prompt=np.arange(6, dtype=np.int64) % cfg.vocab,
                   max_new_tokens=5, temperature=0.0)]
    o1 = server.generate(req)
    o2 = server.generate(req)
    np.testing.assert_array_equal(o1[0], o2[0])


def test_encoder_only_rejected():
    cfg = C.get("hubert-xlarge").reduced()
    with pytest.raises(AssertionError):
        Server(cfg)
