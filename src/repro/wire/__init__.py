"""repro.wire: a real bitstream layer for every FL channel.

The BitMeter books *theoretical* bits; this package makes the accounting
falsifiable.  Channels gain ``encode_up`` / ``decode_up`` /
``encode_down`` / ``decode_down`` hooks that serialize the exact values
the functional core selects (``repro.fl.channels``), the engine's
``wire="audit"`` mode routes a whole host run through encode -> decode
each round (bit-identical trajectory, cf. tests/test_wire.py), and
:meth:`WireSession.reconcile` fails loudly whenever booked bits diverge
from the serialized stream beyond the documented framing overhead.

Layers (lowest first): :mod:`.bitio` (MSB-first bit packing),
:mod:`.codecs` (per-channel-family payloads), :mod:`.frame` (message
envelope + session stream + the reconcile tolerance contract).
"""
from __future__ import annotations

import zlib

from .bitio import (BitReader, BitWriter, WireError,  # noqa: F401
                    WireFormatError, WireIntegrityError)
from .codecs import WireCapacityError  # noqa: F401
from .frame import (DIR_CTRL, DIR_DOWN, DIR_FLUSH_DOWN,  # noqa: F401
                    DIR_FLUSH_UP, DIR_UP, DOWNLINK_DIRS,
                    FRAME_HEADER_BITS, FRAME_OVERHEAD_BITS,
                    FRAME_TRAILER_BITS, MAGIC, Message, RECONCILE_REL_TOL,
                    RECONCILE_TOL_BITS, SERVER, UPLINK_DIRS, VERSION,
                    WastedAttempt, WireSession)


def scheme_wire_id(name: str) -> int:
    """Stable 16-bit scheme identifier for message framing."""
    return zlib.crc32(name.encode("utf-8")) & 0xFFFF
