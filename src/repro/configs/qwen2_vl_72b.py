"""Qwen2-VL 72B: VLM decoder with M-RoPE (vision tower stubbed).  [arXiv:2409.12191]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", arch_type="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, head_dim=128,
    rope_kind="mrope", mrope_sections=(16, 24, 24),
    vlm_image_tokens=1024,  # dynamic-resolution stub: fixed patch-token count
    source="arXiv:2409.12191",
)
