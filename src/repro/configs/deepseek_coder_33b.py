"""DeepSeek-Coder 33B: dense llama-arch GQA.  [arXiv:2401.14196]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", arch_type="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256, head_dim=128,
    source="arXiv:2401.14196",
)
