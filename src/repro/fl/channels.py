"""Composable communication channels for the FL engine (cf. DESIGN.md).

BICompFL's central observation is that uplink and downlink are *both*
compression channels whose costs interact.  This module makes each direction
a first-class object: a :class:`Channel` encodes what one party sends, what
the other party reconstructs, and **how many bits crossed the wire** -- the
bit accounting lives in the channel, not in the training loop.

Functional core
---------------
Every channel is a *pure* function over an explicit state pytree, so the
engine can run the whole multi-round loop as one ``jax.lax.scan`` (the
device-resident fused path, cf. ``engine.FLEngine``):

Uplink channels implement::

    step_up(ctx, state, payload, priors) -> (server_side_estimates, bits, state)

where ``payload`` is the per-active-client message source -- Bernoulli
posteriors ``q`` for the probabilistic-mask path, weight deltas for
conventional FL -- and ``priors`` are the clients' current global-model
estimates (the MRC prior; ignored by the non-stochastic compressors).

Downlink channels implement::

    step_down(ctx, state, update, theta, theta_hat) -> (DownlinkResult, state)

receiving the aggregator's proposed :class:`ServerUpdate` and returning the
*final* server model, the new per-client estimates and the downlink bits.
The downlink owns the final model update because some schemes (sign-EF a la
DoubleSqueeze) have the server itself step with the *compressed* aggregate.

State is any pytree: ``()`` for stateless channels, the error-feedback
memory array for the EF compressors.  ``init_up_state(n, d)`` /
``init_down_state(n, d)`` build the initial state;
``flush_step(state, n, d) -> (residual, bits, state)`` implements the
periodic error-reset of CSER / LIEC.

Bits contract
-------------
``bits`` return values are computed from static shapes and the round's
:class:`BlockPlan`.  Under a *static* plan that makes them plain Python
floats, which lets the fused engine book communication host-side with zero
device syncs.  Under a bucketed adaptive plan (built on device inside the
fused scan body) ``plan.billable`` is a **traced** block count, so ``bits``
becomes a traced f32 scalar; the engine then carries per-round bits through
the scan outputs and books them into the BitMeter after the run.  Channels
must always bill ``plan.billable`` (never ``plan.n_blocks``, which is only
the static segment *capacity*) and must keep the bits expression otherwise
shape-derived, so both representations stay exact.

Object shell
------------
The pre-existing stateful API (``transmit`` / ``distribute`` / ``flush`` /
``reset``) is a thin wrapper over the functional core: the shell owns the
state pytree and threads it through the pure steps.  Instantiate a fresh
channel per run (or ``reset()`` it) exactly as before.

Key-derivation tags reproduce the seed loops exactly, so the engine is
bit-for-bit compatible with the original ``run_bicompfl`` (see
tests/test_engine_parity.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrc
from repro.core.bernoulli import clip01
from repro.core.blocks import BlockPlan  # noqa: F401  (re-export: the plan
                                         # travels with the channel API)
from repro.core.quantizers import (FLOAT_BITS, sign_compress, topk_bits,
                                   topk_compress)

# ---------------------------------------------------------------------------
# Key-derivation tags (shared-randomness schedule, identical to the seed).
# ---------------------------------------------------------------------------

TAG_TRAIN = 1          # per-round local-training keys
TAG_UL_SELECT = 2      # uplink Gumbel selection stream
TAG_DL_SHARED = 3      # downlink candidate stream
TAG_DL_SELECT_COMMON = 4   # downlink selection, common (GR-Reconst)
TAG_DL_SELECT_PRIVATE = 5  # downlink selection, per-client (PR variants)
TAG_COHORT = 6         # jax-native cohort sampling (engine, cohort_rng="jax")

# State pytree of a stateless channel: no leaves, trivially scan-carriable.
EMPTY_STATE: Tuple = ()


def pin(token, x):
    """Pin ``x``'s rounding against re-fusion inside one compiled program.

    The host loop materialises each stage's output between separately
    compiled dispatches; inside the engine's fused scan XLA instead fuses
    values into their consumers and LLVM FMA-contracts chains like
    ``theta - lr * mean(...)`` into a single rounding, breaking bit-parity
    with the host path.  ``optimization_barrier`` is deleted by the CPU
    pipeline and a select on a runtime predicate gets *sunk through* the
    arithmetic, so the robust pin routes the value through integer space:
    ``bitcast_f32->i32 -> add(token) -> bitcast_i32->f32`` where ``token``
    is a *traced* int32 zero (``RoundContext.pin_token``, fed from the scan
    xs so nothing can constant-fold it).  Adding integer zero is the exact
    identity on the bit pattern, and no floating-point rewrite crosses an
    integer op -- the f32 value is forced to its rounded form before any
    consumer sees it.  On the host path ``token`` is None and this is a
    no-op.  Only float32 leaves are touched; other dtypes are exact anyway.
    """
    if token is None:
        return x

    def _pin(v):
        v = jnp.asarray(v)
        if v.dtype != jnp.float32:
            return v
        bits = jax.lax.bitcast_convert_type(v, jnp.int32)
        return jax.lax.bitcast_convert_type(bits + token, jnp.float32)

    return jax.tree.map(_pin, x)


def _vfold(key: jax.Array, ids: jax.Array) -> jax.Array:
    """fold_in(key, i) for every client id i -> stacked keys."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)


def _vclient_keys(kt: jax.Array, ids: jax.Array) -> jax.Array:
    """Per-client private shared randomness, vmapped over ids."""
    return jax.vmap(lambda i: mrc.client_key(kt, i))(ids)


# ---------------------------------------------------------------------------
# Block helpers.  Pad value 0.5 for BOTH q and p => padded entries have zero
# KL and never influence the selected index.  Batched over leading dims.
# ---------------------------------------------------------------------------


def to_blocks(v: jax.Array, size: int) -> jax.Array:
    d = v.shape[-1]
    b = -(-d // size)
    pad = b * size - d
    if pad:
        v = jnp.concatenate([v, jnp.full(v.shape[:-1] + (pad,), 0.5, v.dtype)], axis=-1)
    return v.reshape(v.shape[:-1] + (b, size))


def from_blocks(m: jax.Array, d: int) -> jax.Array:
    return m.reshape(m.shape[:-2] + (-1,))[..., :d]


# ---------------------------------------------------------------------------
# Round context / server update.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RoundContext:
    """Everything a channel may need about the current global round.

    In the fused engine path ``t``, ``key`` and ``active`` are traced scan
    values (``active`` a jnp int vector); channels must only use them in
    traceable positions.  Cohort *size* stays static either way.
    """

    t: Any
    key: jax.Array        # kt = mrc.round_key(base, t) -- shared randomness
    n_clients: int
    d: int
    active: Any           # sorted global ids of the participating cohort
    plan: Optional[BlockPlan] = None
    pin_token: Any = None  # traced int32 zero in the fused path (cf. pin)

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def active_ids(self) -> jax.Array:
        return jnp.asarray(self.active, dtype=jnp.int32)


@dataclass(frozen=True)
class ServerUpdate:
    """Aggregator output: the proposed next server model.

    ``delta`` carries the aggregate update direction for delta-space schemes
    (``theta = theta_prev - lr * delta``); it is None for model-space schemes
    (BiCompFL) whose aggregate *is* the new model.
    """

    theta: jax.Array
    delta: Optional[jax.Array] = None
    lr: float = 1.0


class DownlinkResult(NamedTuple):
    theta: jax.Array      # final server model after the downlink
    theta_hat: jax.Array  # (n_clients, d) client estimates
    bits: float


@runtime_checkable
class UplinkChannel(Protocol):
    def init_up_state(self, n: int, d: int): ...

    def step_up(self, ctx: RoundContext, state, payload: jax.Array,
                priors: jax.Array) -> Tuple[jax.Array, float, Any]: ...

    def transmit(self, ctx: RoundContext, payload: jax.Array,
                 priors: jax.Array) -> Tuple[jax.Array, float]: ...


@runtime_checkable
class DownlinkChannel(Protocol):
    broadcast_shareable: bool

    def init_down_state(self, n: int, d: int): ...

    def step_down(self, ctx: RoundContext, state, update: ServerUpdate,
                  theta: jax.Array,
                  theta_hat: jax.Array) -> Tuple[DownlinkResult, Any]: ...

    def distribute(self, ctx: RoundContext, update: ServerUpdate,
                   theta: jax.Array, theta_hat: jax.Array) -> DownlinkResult: ...


# ---------------------------------------------------------------------------
# Shell mixins: the stateful object API over the pure step functions.
# ---------------------------------------------------------------------------


class StatelessUplink:
    """Object shell + trivial state for uplinks without memory."""

    def init_up_state(self, n: int, d: int):
        return EMPTY_STATE

    def transmit(self, ctx, payload, priors):
        out, bits, _ = self.step_up(ctx, EMPTY_STATE, payload, priors)
        return out, bits

    def flush_step(self, state, n: int, d: int):
        return 0.0, 0.0, state

    def flush(self, n: int, d: int):
        return 0.0, 0.0


class StatelessDownlink:
    """Object shell + trivial state for downlinks without memory."""

    def init_down_state(self, n: int, d: int):
        return EMPTY_STATE

    def distribute(self, ctx, update, theta, theta_hat):
        res, _ = self.step_down(ctx, EMPTY_STATE, update, theta, theta_hat)
        return res

    def flush_step(self, state, n: int, d: int):
        return 0.0, 0.0, state

    def flush(self, n: int, d: int):
        return 0.0, 0.0


# ---------------------------------------------------------------------------
# MRC channels (the paper's C_mrc, fixed-size blocks / adaptive segments).
# ---------------------------------------------------------------------------


@dataclass
class MRCFixedChannel(StatelessUplink):
    """Uplink MRC over fixed-size blocks, vmapped across the cohort.

    ``shared=True`` (GR) lets every client draw candidates from the *common*
    round key; ``shared=False`` (PR) vmaps over per-client private keys.
    """

    n_is: int = 256
    n_samples: int = 1
    shared: bool = True
    chunk: int = 16
    logw_fn: Any = None

    def step_up(self, ctx, state, payload, priors):
        plan = ctx.plan
        kt = ctx.key
        qb = to_blocks(clip01(payload), plan.size)   # (n_act, B, S)
        pb = to_blocks(clip01(priors), plan.size)
        sels = _vfold(jax.random.fold_in(kt, TAG_UL_SELECT), ctx.active_ids)

        def one(skey, sel, q_i, p_i):
            _, q_hat_b = mrc.transmit_fixed(
                skey, sel, q_i, p_i, n_is=self.n_is, n_samples=self.n_samples,
                chunk=self.chunk, logw_fn=self.logw_fn)
            return q_hat_b

        if self.shared:
            q_hat_b = jax.vmap(lambda sel, q, p: one(kt, sel, q, p))(sels, qb, pb)
        else:
            skeys = _vclient_keys(kt, ctx.active_ids)
            q_hat_b = jax.vmap(one)(skeys, sels, qb, pb)
        bits = ctx.n_active * self.n_samples * plan.billable * math.log2(self.n_is)
        return from_blocks(q_hat_b, ctx.d), bits, state


@dataclass
class MRCAdaptiveChannel(StatelessUplink):
    """Uplink MRC over variable-size segments (Isik et al. 2024 allocation)."""

    n_is: int = 256
    n_samples: int = 1
    shared: bool = True

    def step_up(self, ctx, state, payload, priors):
        plan = ctx.plan
        kt = ctx.key
        seg = jnp.asarray(plan.seg_ids)
        sels = _vfold(jax.random.fold_in(kt, TAG_UL_SELECT), ctx.active_ids)

        def one(skey, sel, q_i, p_i):
            _, q_hat = mrc.transmit_segments(
                skey, sel, q_i, clip01(p_i), seg, n_is=self.n_is,
                n_seg=plan.n_blocks, n_samples=self.n_samples)
            return q_hat

        q = clip01(payload)
        if self.shared:
            q_hat = jax.vmap(lambda sel, q_i, p: one(kt, sel, q_i, p))(sels, q, priors)
        else:
            skeys = _vclient_keys(kt, ctx.active_ids)
            q_hat = jax.vmap(one)(skeys, sels, q, priors)
        bits = ctx.n_active * self.n_samples * plan.billable * math.log2(self.n_is)
        return q_hat, bits, state


@dataclass
class QuantizedMRCUplink(StatelessUplink):
    """Conventional-FL uplink: stochastic sign -> MRC vs the Ber(1/2) prior.

    Each client maps its delta to a Bernoulli posterior q = sigmoid(delta/K)
    with per-client temperature K = mean|delta| (32-bit side information),
    conveys ``n_samples`` MRC samples against the uninformative prior, and
    the server reconstructs the direction (2*q_hat - 1) * K.
    """

    n_is: int = 256
    n_samples: int = 1
    chunk: int = 16
    logw_fn: Any = None
    side_info_bits: float = FLOAT_BITS

    def step_up(self, ctx, state, payload, priors):
        plan = ctx.plan
        kt = ctx.key
        d = ctx.d
        p_blocks = jnp.full((plan.n_blocks, plan.size), 0.5, jnp.float32)
        sels = _vfold(jax.random.fold_in(kt, TAG_UL_SELECT), ctx.active_ids)

        # Each K fans into the posterior and the reconstruction rescale; pin
        # the vector so the fused engine rounds like the host loop.
        Ks = pin(ctx.pin_token,
                 jax.vmap(lambda delta: jnp.mean(jnp.abs(delta)) + 1e-12)(payload))

        def one(sel, delta, K):
            q_i = clip01(jax.nn.sigmoid(delta / K))
            _, q_hat_b = mrc.transmit_fixed(
                kt, sel, to_blocks(q_i, plan.size), p_blocks, n_is=self.n_is,
                n_samples=self.n_samples, chunk=self.chunk, logw_fn=self.logw_fn)
            return (2.0 * from_blocks(q_hat_b, d) - 1.0) * K

        g_hat = jax.vmap(one)(sels, payload, Ks)
        bits = ctx.n_active * (self.n_samples * plan.billable * math.log2(self.n_is)
                               + self.side_info_bits)
        return g_hat, bits, state


# ---------------------------------------------------------------------------
# BiCompFL downlinks.
# ---------------------------------------------------------------------------


@dataclass
class IndexRelayDownlink(StatelessDownlink):
    """GR downlink: relay the other clients' uplink indices.

    With common candidates every client reconstructs the identical global
    model, so no recomputation is needed -- only the bits are booked:
    each client receives the (n-1) other clients' index streams (plus
    optional per-client side information, e.g. the CFL temperatures).
    """

    n_is: int = 256
    n_samples: int = 1           # relayed samples per client (n_UL)
    side_info_bits: float = 0.0
    broadcast_shareable: bool = True

    def step_down(self, ctx, state, update, theta, theta_hat):
        n = ctx.n_clients
        th = update.theta
        bits = n * (n - 1) * (self.n_samples * ctx.plan.billable
                              * math.log2(self.n_is) + self.side_info_bits)
        return DownlinkResult(th, jnp.tile(th[None], (n, 1)), bits), state


@dataclass
class MRCBroadcastDownlink(StatelessDownlink):
    """GR-Reconst downlink: one MRC re-transmission against the common prior;
    all clients share candidates and end with the same (noisy) estimate."""

    n_is: int = 256
    n_samples: int = 1           # n_DL
    chunk: int = 16
    logw_fn: Any = None
    broadcast_shareable: bool = True

    def step_down(self, ctx, state, update, theta, theta_hat):
        kt, plan, d = ctx.key, ctx.plan, ctx.d
        skey = jax.random.fold_in(kt, TAG_DL_SHARED)
        sel = jax.random.fold_in(kt, TAG_DL_SELECT_COMMON)
        p_common = clip01(theta_hat[0])
        tgt = update.theta
        if plan.adaptive:
            _, est = mrc.transmit_segments(
                skey, sel, tgt, p_common, jnp.asarray(plan.seg_ids),
                n_is=self.n_is, n_seg=plan.n_blocks, n_samples=self.n_samples)
        else:
            _, est_b = mrc.transmit_fixed(
                skey, sel, to_blocks(tgt, plan.size), to_blocks(p_common, plan.size),
                n_is=self.n_is, n_samples=self.n_samples, chunk=self.chunk,
                logw_fn=self.logw_fn)
            est = from_blocks(est_b, d)
        bits = ctx.n_clients * self.n_samples * plan.billable * math.log2(self.n_is)
        return DownlinkResult(
            tgt, jnp.tile(clip01(est)[None], (ctx.n_clients, 1)), bits), state


@dataclass
class MRCPrivateDownlink(StatelessDownlink):
    """PR downlink: per-client MRC against each client's own prior, vmapped
    over per-client private keys.  Under partial participation only the
    active cohort receives the downlink; stragglers keep stale estimates."""

    n_is: int = 256
    n_samples: int = 1           # n_DL
    chunk: int = 16
    logw_fn: Any = None
    broadcast_shareable: bool = False

    def step_down(self, ctx, state, update, theta, theta_hat):
        kt, plan, d = ctx.key, ctx.plan, ctx.d
        ids = ctx.active_ids
        skeys = jax.vmap(lambda k: jax.random.fold_in(k, TAG_DL_SHARED))(
            _vclient_keys(kt, ids))
        sels = _vfold(jax.random.fold_in(kt, TAG_DL_SELECT_PRIVATE), ids)
        priors = clip01(theta_hat[ids])
        tgt = update.theta
        if plan.adaptive:
            seg = jnp.asarray(plan.seg_ids)

            def one(skey, sel, p_i):
                _, est = mrc.transmit_segments(
                    skey, sel, tgt, p_i, seg, n_is=self.n_is,
                    n_seg=plan.n_blocks, n_samples=self.n_samples)
                return est
        else:
            tb = to_blocks(tgt, plan.size)

            def one(skey, sel, p_i):
                _, est_b = mrc.transmit_fixed(
                    skey, sel, tb, to_blocks(p_i, plan.size), n_is=self.n_is,
                    n_samples=self.n_samples, chunk=self.chunk, logw_fn=self.logw_fn)
                return from_blocks(est_b, d)

        est = jax.vmap(one)(skeys, sels, priors)
        theta_hat = theta_hat.at[ids].set(clip01(est))
        bits = ctx.n_active * self.n_samples * plan.billable * math.log2(self.n_is)
        return DownlinkResult(tgt, theta_hat, bits), state


@dataclass
class SplitBlockDownlink(StatelessDownlink):
    """PR-SplitDL: each client receives MRC only for a disjoint 1/n of the
    blocks (downlink cost / n); the rest of its estimate stays as-is.

    Clients own interleaved block subsets arange(i, B, n).  The per-client
    subsets are ragged when B % n != 0, so they are padded to the common
    maximum with one sentinel block whose result is discarded -- this keeps
    the whole downlink a single vmapped transmission.  Fixed blocks only.
    """

    n_is: int = 256
    n_samples: int = 1           # n_DL
    chunk: int = 16
    logw_fn: Any = None
    broadcast_shareable: bool = False

    def step_down(self, ctx, state, update, theta, theta_hat):
        kt, plan, d = ctx.key, ctx.plan, ctx.d
        if plan.adaptive:
            raise NotImplementedError("SplitDL is defined on fixed blocks")
        n, size, n_blocks = ctx.n_clients, plan.size, plan.n_blocks
        max_len = -(-n_blocks // n)
        # Padded ownership table; sentinel index n_blocks targets a dummy row.
        own_pad = np.full((n, max_len), n_blocks, np.int32)
        for i in range(n):
            own = np.arange(i, n_blocks, n, dtype=np.int32)
            own_pad[i, :len(own)] = own
        own_pad = jnp.asarray(own_pad)

        tb = to_blocks(update.theta, size)                       # (B, S)
        dummy = jnp.full((1, size), 0.5, tb.dtype)
        tb_ext = jnp.concatenate([tb, dummy])
        hb_all = to_blocks(clip01(theta_hat), size)              # (n, B, S)
        ids = jnp.arange(n, dtype=jnp.int32)
        skeys = jax.vmap(lambda k: jax.random.fold_in(k, TAG_DL_SHARED))(
            _vclient_keys(kt, ids))
        sels = _vfold(jax.random.fold_in(kt, TAG_DL_SELECT_PRIVATE), ids)
        chunk = min(self.chunk, max_len)

        def one(skey, sel, hb_i, own_i):
            hb_ext = jnp.concatenate([hb_i, dummy])
            _, est_b = mrc.transmit_fixed(
                skey, sel, tb_ext[own_i], hb_ext[own_i], n_is=self.n_is,
                n_samples=self.n_samples, chunk=chunk, logw_fn=self.logw_fn)
            hb_ext = hb_ext.at[own_i].set(clip01(est_b))
            return from_blocks(hb_ext[:n_blocks], d)

        theta_hat = jax.vmap(one)(skeys, sels, hb_all, own_pad)
        bits = n * self.n_samples * max_len * math.log2(self.n_is)
        return DownlinkResult(update.theta, theta_hat, bits), state


# ---------------------------------------------------------------------------
# Non-stochastic baseline channels.
# ---------------------------------------------------------------------------


@dataclass
class DenseChannel(StatelessUplink, StatelessDownlink):
    """Lossless 32-bit transmission; usable on either direction."""

    bits_per_value: float = FLOAT_BITS
    broadcast_shareable: bool = True

    def step_up(self, ctx, state, payload, priors):
        return payload, ctx.n_active * ctx.d * self.bits_per_value, state

    def step_down(self, ctx, state, update, theta, theta_hat):
        th = update.theta
        return DownlinkResult(th, jnp.tile(th[None], (ctx.n_clients, 1)),
                              ctx.n_clients * ctx.d * self.bits_per_value), state

    def flush_step(self, state, n, d):
        # Stateless: a periodic sync through a dense channel only costs bits.
        return 0.0, n * d * self.bits_per_value, state

    def flush(self, n, d):
        return 0.0, n * d * self.bits_per_value


@dataclass
class SignEFChannel:
    """Sign compression with error feedback; ``passes>1`` repeats compression
    on the residual (Neolithic's R-pass scheme, ~``passes`` bits/param).

    As an uplink it keeps per-client EF memory (n, d); as a downlink it
    keeps the server-side memory (d,) and steps server *and* clients with
    the compressed aggregate (DoubleSqueeze).
    """

    passes: int = 1
    broadcast_shareable: bool = True
    _e: Optional[jax.Array] = field(default=None, repr=False)

    def _compress(self, v):
        c = sign_compress(v)
        for _ in range(self.passes - 1):
            c = c + sign_compress(v - c)
        return c

    # -- functional core --------------------------------------------------
    def init_up_state(self, n, d):
        return jnp.zeros((n, d), jnp.float32)

    def init_down_state(self, n, d):
        return jnp.zeros((d,), jnp.float32)

    def step_up(self, ctx, e, payload, priors):
        if ctx.n_active != ctx.n_clients:
            raise ValueError("error-feedback uplinks require full participation")
        acc = payload + e
        c = jax.vmap(self._compress)(acc)
        bits = ctx.n_clients * self.passes * (ctx.d + FLOAT_BITS)
        return c, bits, acc - c

    def step_down(self, ctx, e, update, theta, theta_hat):
        g = update.delta if update.delta is not None \
            else (theta - update.theta) / update.lr
        agg = g + e
        c_s = self._compress(agg)
        bits = ctx.n_clients * self.passes * (ctx.d + FLOAT_BITS)
        return DownlinkResult(theta - update.lr * c_s,
                              theta_hat - update.lr * c_s[None, :], bits), agg - c_s

    def flush_step(self, e, n, d):
        r = jnp.mean(e, axis=0) if e.ndim == 2 else e
        return r, n * d * FLOAT_BITS, jnp.zeros_like(e)

    # -- object shell ------------------------------------------------------
    def transmit(self, ctx, payload, priors):
        if self._e is None:
            self._e = jnp.zeros_like(payload)
        out, bits, self._e = self.step_up(ctx, self._e, payload, priors)
        return out, bits

    def distribute(self, ctx, update, theta, theta_hat):
        if self._e is None:
            self._e = jnp.zeros_like(theta)
        res, self._e = self.step_down(ctx, self._e, update, theta, theta_hat)
        return res

    def flush(self, n, d):
        if self._e is None:
            return 0.0, n * d * FLOAT_BITS
        r, bits, self._e = self.flush_step(self._e, n, d)
        return r, bits

    def reset(self):
        self._e = None


@dataclass
class TopKEFChannel:
    """Top-k sparsification with error feedback (M3 uplink, k = d/n)."""

    k: int = 1
    _e: Optional[jax.Array] = field(default=None, repr=False)

    # -- functional core --------------------------------------------------
    def init_up_state(self, n, d):
        return jnp.zeros((n, d), jnp.float32)

    def step_up(self, ctx, e, payload, priors):
        if ctx.n_active != ctx.n_clients:
            raise ValueError("error-feedback uplinks require full participation")
        acc = payload + e
        c = jax.vmap(lambda v: topk_compress(v, self.k))(acc)
        return c, ctx.n_clients * topk_bits(ctx.d, self.k), acc - c

    def flush_step(self, e, n, d):
        return jnp.mean(e, axis=0), n * d * FLOAT_BITS, jnp.zeros_like(e)

    # -- object shell ------------------------------------------------------
    def transmit(self, ctx, payload, priors):
        if self._e is None:
            self._e = jnp.zeros_like(payload)
        out, bits, self._e = self.step_up(ctx, self._e, payload, priors)
        return out, bits

    def flush(self, n, d):
        if self._e is None:
            return 0.0, n * d * FLOAT_BITS
        r, bits, self._e = self.flush_step(self._e, n, d)
        return r, bits

    def reset(self):
        self._e = None


@dataclass
class SliceDownlink(StatelessDownlink):
    """M3 downlink: each client receives a disjoint dense 1/n model slice;
    client estimates diverge (no broadcast saving possible).

    ``k`` (slice width) defaults to d/n at runtime; pass it explicitly to
    keep it consistent with a paired Top-k uplink budget."""

    k: Optional[int] = None
    broadcast_shareable: bool = False

    def step_down(self, ctx, state, update, theta, theta_hat):
        n, d = ctx.n_clients, ctx.d
        th = update.theta
        k = self.k if self.k is not None else max(d // n, 1)
        new_hat = []
        for i in range(n):
            lo = i * k
            hi = d if i == n - 1 else min((i + 1) * k, d)
            new_hat.append(theta_hat[i].at[lo:hi].set(th[lo:hi]))
        return DownlinkResult(th, jnp.stack(new_hat),
                              n * (d / n) * FLOAT_BITS), state
