"""Non-stochastic bi-directional compression baselines (paper Section 4).

All baselines share one skeleton: clients compute a local delta ("gradient"),
apply an uplink compressor (with error feedback where the original scheme
uses it), the federator aggregates + optionally compresses the downlink, and
bits are booked from what is actually transmitted.

Schemes (with the simplifications we make, cf. DESIGN.md):

* fedavg         : dense 32-bit both directions.
* memsgd         : Stich et al. 2018  -- sign + EF uplink, dense downlink.
* doublesqueeze  : Tang et al. 2019  -- sign + EF uplink AND downlink.
* neolithic      : Huang et al. 2022 -- as doublesqueeze with R=2 compression
                   passes per direction (2 bits/param effective).
* cser           : Xie et al. 2020   -- sign + EF uplink, dense downlink,
                   periodic error reset (period 50) adds an amortized sync.
* liec           : Cheng et al. 2024 -- bidirectional sign with immediate
                   local error compensation + periodic averaging (period 50).
* m3             : Gruntkowska et al. 2024 -- TopK(d/n) + EF uplink; downlink
                   sends each client a *disjoint* 1/n model slice (dense);
                   clients hold diverging model estimates.

``run_baseline`` is a thin wrapper: each scheme is a
(uplink, downlink, aggregator) factory in :mod:`repro.fl.registry`, executed
by the shared :class:`~repro.fl.engine.FLEngine` round loop.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax

from .data import Dataset
from .engine import FLEngine
from .registry import ALL_BASELINES, baseline_spec  # noqa: F401  (re-export)


@dataclass
class BaselineConfig:
    scheme: str = "fedavg"
    rounds: int = 30
    server_lr: float = 1.0
    seed: int = 0
    eval_every: int = 1
    reset_period: int = 50   # CSER / LIEC periodic sync


def run_baseline(task, theta0: jax.Array, shards: Dataset,
                 cfg: BaselineConfig) -> Dict[str, Any]:
    n = int(shards.x.shape[0])
    d = int(theta0.shape[0])
    spec = baseline_spec(cfg.scheme, n=n, d=d, server_lr=cfg.server_lr,
                         reset_period=cfg.reset_period)
    return FLEngine(task, spec).run(shards, theta0, rounds=cfg.rounds,
                                    seed=cfg.seed, eval_every=cfg.eval_every)
