"""BiCompFL federator loops (paper Algorithms 1 & 2 + variants).

Implemented variants (cfg.variant):

* ``GR``          -- Alg. 1: global shared randomness; the federator *relays*
                     the clients' MRC indices, every client reconstructs the
                     identical global model (no extra compression noise).
* ``GR-Reconst``  -- the suboptimal ablation: the federator reconstructs the
                     global model and re-transmits it via a second MRC round
                     (common candidates -> all clients equal estimates).
* ``PR``          -- Alg. 2: private shared randomness only; per-client MRC
                     on the downlink; clients hold distinct estimates.
* ``PR-SplitDL``  -- PR, but the downlink sends each client only a disjoint
                     1/n slice of the blocks (downlink cost / n).

The uplink/downlink priors are the clients' latest global-model estimates
(theta_hat), exactly as the paper settles on (lambda = 1).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrc
from repro.core.bernoulli import bern_kl, clip01
from repro.core.bitmeter import BitMeter
from repro.core.blocks import AdaptiveAllocation, FixedAllocation
from .data import Dataset


# ---------------------------------------------------------------------------
# Block helpers.  Pad value 0.5 for BOTH q and p => padded entries have zero
# KL and never influence the selected index.
# ---------------------------------------------------------------------------


def to_blocks(v: jax.Array, size: int) -> jax.Array:
    d = v.shape[-1]
    b = -(-d // size)
    pad = b * size - d
    if pad:
        v = jnp.concatenate([v, jnp.full(v.shape[:-1] + (pad,), 0.5, v.dtype)], axis=-1)
    return v.reshape(v.shape[:-1] + (b, size))


def from_blocks(m: jax.Array, d: int) -> jax.Array:
    return m.reshape(m.shape[:-2] + (-1,))[..., :d]


@dataclass
class BiCompFLConfig:
    variant: str = "GR"          # GR | GR-Reconst | PR | PR-SplitDL
    allocation: Any = field(default_factory=lambda: FixedAllocation(256))
    n_is: int = 256
    n_ul: int = 1
    n_dl: Optional[int] = None   # default: n_clients * n_ul (paper)
    rounds: int = 30
    seed: int = 0
    eval_every: int = 1
    chunk: int = 16              # MRC encode block-chunk (memory knob)
    logw_fn: Any = None          # optionally the Pallas kernel closure
    participation: float = 1.0   # fraction of clients per round; < 1 only
                                 # valid for PR variants (the paper notes
                                 # partial participation is incompatible
                                 # with global shared randomness)


def _uplink_bits(n_clients, n_ul, n_blocks, n_is):
    return n_clients * n_ul * n_blocks * math.log2(n_is)


def run_bicompfl(task, shards: Dataset, cfg: BiCompFLConfig) -> Dict[str, Any]:
    """Run probabilistic-mask BiCompFL; returns history + bit accounting."""
    n = int(shards.x.shape[0])
    d = task.d
    n_dl = cfg.n_dl if cfg.n_dl is not None else n * cfg.n_ul
    base = jax.random.PRNGKey(cfg.seed)
    is_gr = cfg.variant.startswith("GR")
    meter = BitMeter(n_clients=n, d=d, broadcast_downlink_shareable=is_gr)

    theta_hat = jnp.tile(task.init_theta()[None], (n, 1))  # per-client estimates
    history: List[Dict[str, float]] = []
    adaptive = isinstance(cfg.allocation, AdaptiveAllocation)

    if cfg.participation < 1.0 and cfg.variant != "PR":
        raise ValueError("partial participation requires private shared "
                         "randomness (the PR variant); GR needs all clients "
                         "to track the common candidate stream, and SplitDL "
                         "partitions the downlink across the full cohort")
    n_active = max(1, int(round(cfg.participation * n)))
    rng = np.random.default_rng(cfg.seed + 17)

    log2_nis = math.log2(cfg.n_is)
    for t in range(cfg.rounds):
        kt = mrc.round_key(base, t)
        active = sorted(rng.choice(n, size=n_active, replace=False)) \
            if n_active < n else list(range(n))
        train_keys = jax.random.split(jax.random.fold_in(kt, 1), n)

        # ---- local training (vmapped over clients) ----------------------
        q = jax.vmap(task.local_train)(theta_hat, shards.x, shards.y, train_keys)
        q = clip01(q)

        # ---- block allocation (host-side control plane) -----------------
        kl_mean = np.asarray(jnp.mean(jax.vmap(bern_kl)(q, clip01(theta_hat)), axis=0))
        size, n_blocks, seg_ids, overhead = cfg.allocation.plan(kl_mean, d)

        # ---- uplink: each client conveys n_UL posterior samples ----------
        def up_one(i, q_i, p_i):
            skey = kt if is_gr else mrc.client_key(kt, i)
            sel = jax.random.fold_in(jax.random.fold_in(kt, 2), i)
            if adaptive:
                idxs, q_hat = mrc.transmit_segments(
                    skey, sel, q_i, clip01(p_i), jnp.asarray(seg_ids),
                    n_is=cfg.n_is, n_seg=n_blocks, n_samples=cfg.n_ul)
                return idxs, q_hat
            qb, pb = to_blocks(q_i, size), to_blocks(clip01(p_i), size)
            idxs, q_hat_b = mrc.transmit_fixed(
                skey, sel, qb, pb, n_is=cfg.n_is, n_samples=cfg.n_ul,
                chunk=cfg.chunk, logw_fn=cfg.logw_fn)
            return idxs, from_blocks(q_hat_b, d)

        q_hats = []
        for i in active:
            _, q_hat_i = up_one(i, q[i], theta_hat[i])
            q_hats.append(q_hat_i)
        q_hat = jnp.stack(q_hats)                 # (n_active, d) fed. estimates
        theta_next = jnp.mean(q_hat, axis=0)           # server global model

        ul_bits = _uplink_bits(len(active), cfg.n_ul, n_blocks, cfg.n_is)

        # ---- downlink ----------------------------------------------------
        if cfg.variant == "GR":
            # Relay the other clients' indices; with common candidates every
            # client reconstructs q_hat exactly => estimate == server model.
            theta_hat = jnp.tile(theta_next[None], (n, 1))
            dl_bits = n * (n - 1) * cfg.n_ul * n_blocks * log2_nis
        elif cfg.variant == "GR-Reconst":
            skey = jax.random.fold_in(kt, 3)
            sel = jax.random.fold_in(kt, 4)
            p_common = clip01(theta_hat[0])
            if adaptive:
                _, est = mrc.transmit_segments(
                    skey, sel, theta_next, p_common, jnp.asarray(seg_ids),
                    n_is=cfg.n_is, n_seg=n_blocks, n_samples=n_dl)
            else:
                _, est_b = mrc.transmit_fixed(
                    skey, sel, to_blocks(theta_next, size), to_blocks(p_common, size),
                    n_is=cfg.n_is, n_samples=n_dl, chunk=cfg.chunk, logw_fn=cfg.logw_fn)
                est = from_blocks(est_b, d)
            theta_hat = jnp.tile(clip01(est)[None], (n, 1))
            dl_bits = n * n_dl * n_blocks * log2_nis
        elif cfg.variant == "PR":
            # partial participation: only active clients receive the
            # downlink; stragglers keep their stale estimates (paper Sec. 3:
            # PR is the variant compatible with partial participation)
            new_hats = list(theta_hat)
            for i in active:
                skey = jax.random.fold_in(mrc.client_key(kt, i), 3)
                sel = jax.random.fold_in(jax.random.fold_in(kt, 5), i)
                if adaptive:
                    _, est = mrc.transmit_segments(
                        skey, sel, theta_next, clip01(theta_hat[i]), jnp.asarray(seg_ids),
                        n_is=cfg.n_is, n_seg=n_blocks, n_samples=n_dl)
                else:
                    _, est_b = mrc.transmit_fixed(
                        skey, sel, to_blocks(theta_next, size),
                        to_blocks(clip01(theta_hat[i]), size),
                        n_is=cfg.n_is, n_samples=n_dl, chunk=cfg.chunk, logw_fn=cfg.logw_fn)
                    est = from_blocks(est_b, d)
                new_hats[i] = clip01(est)
            theta_hat = jnp.stack(new_hats)
            dl_bits = len(active) * n_dl * n_blocks * log2_nis
        elif cfg.variant == "PR-SplitDL":
            if adaptive:
                raise NotImplementedError("SplitDL is defined on fixed blocks")
            tb = to_blocks(theta_next, size)           # (B, S)
            new_hats = []
            blocks_per_client = 0
            for i in range(n):
                own = np.arange(i, n_blocks, n)         # disjoint 1/n of blocks
                blocks_per_client = max(blocks_per_client, len(own))
                skey = jax.random.fold_in(mrc.client_key(kt, i), 3)
                sel = jax.random.fold_in(jax.random.fold_in(kt, 5), i)
                hb = to_blocks(clip01(theta_hat[i]), size)
                _, est_b = mrc.transmit_fixed(
                    skey, sel, tb[own], hb[own], n_is=cfg.n_is, n_samples=n_dl,
                    chunk=min(cfg.chunk, max(len(own), 1)), logw_fn=cfg.logw_fn)
                hb = hb.at[own].set(clip01(est_b))
                new_hats.append(from_blocks(hb, d))
            theta_hat = jnp.stack(new_hats)
            dl_bits = n * n_dl * blocks_per_client * log2_nis
        else:
            raise ValueError(cfg.variant)

        meter.add_round(ul_bits, dl_bits, overhead_bits=overhead * n)

        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            acc = task.evaluate(theta_next)
            history.append({"round": t + 1, "acc": float(acc),
                            "cum_bits": meter.total_bits,
                            "bpp_so_far": meter.total_bpp})

    return {"history": history, "meter": meter.summary(),
            "theta": theta_next, "theta_hat": theta_hat,
            "final_acc": history[-1]["acc"] if history else float("nan"),
            "max_acc": max(h["acc"] for h in history) if history else float("nan")}


# ---------------------------------------------------------------------------
# BiCompFL-GR-CFL: conventional FL with stochastic sign + MRC (Section 4/5).
# ---------------------------------------------------------------------------


@dataclass
class CFLConfig:
    # CFL compression is near-element-wise (paper Sec. 4): a *small* block
    # keeps per-block d_KL(q || 1/2) within the log(n_is) MRC budget --
    # stochastic-sign posteriors sit far from the uninformative prior.
    n_is: int = 256
    n_ul: int = 1
    block_size: int = 16
    rounds: int = 30
    server_lr: float = 1.0
    seed: int = 0
    eval_every: int = 1
    chunk: int = 16
    temperature: str = "auto"    # K: "auto" => mean |delta| per client
    logw_fn: Any = None


def run_bicompfl_cfl(task, theta0: jax.Array, shards: Dataset, cfg: CFLConfig) -> Dict[str, Any]:
    """BiCompFL-GR applied to conventional FL with stochastic SignSGD.

    Clients quantize their local delta with q = sigmoid(delta / K), convey
    samples via MRC against the uninformative prior p = 1/2, the federator
    averages the reconstructed directions (2*q_hat - 1) and steps; indices
    are relayed on the downlink (global randomness) so the clients track the
    identical global model.
    """
    n = int(shards.x.shape[0])
    d = int(theta0.shape[0])
    base = jax.random.PRNGKey(cfg.seed)
    meter = BitMeter(n_clients=n, d=d, broadcast_downlink_shareable=True)
    theta = theta0
    n_blocks = -(-d // cfg.block_size)
    log2_nis = math.log2(cfg.n_is)
    history: List[Dict[str, float]] = []

    p_blocks = jnp.full((n_blocks, cfg.block_size), 0.5, jnp.float32)

    for t in range(cfg.rounds):
        kt = mrc.round_key(base, t)
        train_keys = jax.random.split(jax.random.fold_in(kt, 1), n)
        deltas = jax.vmap(task.local_train)(
            jnp.tile(theta[None], (n, 1)), shards.x, shards.y, train_keys)  # (n, d)

        g_hats = []
        for i in range(n):
            delta = deltas[i]
            K = jnp.mean(jnp.abs(delta)) + 1e-12
            q_i = clip01(jax.nn.sigmoid(delta / K))
            sel = jax.random.fold_in(jax.random.fold_in(kt, 2), i)
            _, q_hat_b = mrc.transmit_fixed(
                kt, sel, to_blocks(q_i, cfg.block_size), p_blocks,
                n_is=cfg.n_is, n_samples=cfg.n_ul, chunk=cfg.chunk, logw_fn=cfg.logw_fn)
            q_hat = from_blocks(q_hat_b, d)
            g_hats.append((2.0 * q_hat - 1.0) * K)     # scale is 32-bit side info
        g_hat = jnp.mean(jnp.stack(g_hats), axis=0)
        theta = theta - cfg.server_lr * g_hat

        ul = _uplink_bits(n, cfg.n_ul, n_blocks, cfg.n_is) + 32 * n  # + scales
        dl = n * (n - 1) * cfg.n_ul * n_blocks * log2_nis + 32 * n * (n - 1)
        meter.add_round(ul, dl)

        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            acc = task.evaluate(theta)
            history.append({"round": t + 1, "acc": float(acc),
                            "cum_bits": meter.total_bits})

    return {"history": history, "meter": meter.summary(), "theta": theta,
            "final_acc": history[-1]["acc"] if history else float("nan"),
            "max_acc": max(h["acc"] for h in history) if history else float("nan")}
