"""Batched serving loop: prefill + decode with a static KV cache.

``Server`` drives the same ``serve_step``/``prefill_step`` the dry-run
lowers, against a real (small) model on whatever devices exist.  Requests
are batched greedily; generation is temperature sampling off the
vocab-sharded logits.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.models import sharding, transformer as T
from repro.models.config import ArchConfig


@dataclass
class Request:
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 => greedy


class Server:
    def __init__(self, cfg: ArchConfig, *, max_batch: int = 8,
                 max_seq: int = 256, mesh: Optional[Mesh] = None, seed: int = 0):
        assert cfg.supports_decode, "encoder-only archs cannot be served"
        sharding.set_mesh(mesh)
        self.cfg = cfg
        self.model = T.build(cfg)
        self.max_batch, self.max_seq = max_batch, max_seq
        key = jax.random.PRNGKey(seed)
        self.params, _ = T.init_params(self.model, key)
        self.key = jax.random.fold_in(key, 7)

        def step(params, cache, tokens, pos):
            return T.serve_step(self.model, params, cache, tokens, pos)

        self._step = jax.jit(step, donate_argnums=(1,))

    def load_params(self, params):
        self.params = params

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        lf = logits[:, -1].astype(jnp.float32)
        if temperature <= 0.0:
            return jnp.argmax(lf, axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, lf / temperature, axis=-1).astype(jnp.int32)

    def generate(self, requests: List[Request]) -> List[np.ndarray]:
        """Greedy batched generation: one shared cache, per-request lengths."""
        assert len(requests) <= self.max_batch
        b = len(requests)
        cache = T.init_cache(self.model, b, self.max_seq)
        max_prompt = max(len(r.prompt) for r in requests)
        max_new = max(r.max_new_tokens for r in requests)

        # teacher-forced prefill via repeated decode steps (token-parallel
        # prefill exists as prefill_step; the step loop keeps the example
        # dependency-free of cache plumbing between the two paths)
        toks = np.zeros((b, max_prompt), np.int32)
        for i, r in enumerate(requests):
            toks[i, max_prompt - len(r.prompt):] = r.prompt  # left-pad
        outs = [[] for _ in range(b)]
        last = None
        for t in range(max_prompt + max_new - 1):
            if t < max_prompt:
                cur = jnp.asarray(toks[:, t:t + 1])
            else:
                cur = last
            logits, cache = self._step(self.params, cache, cur,
                                       jnp.int32(t))
            nxt = self._sample(logits, max(r.temperature for r in requests))
            last = nxt[:, None]
            if t >= max_prompt - 1:
                arr = np.asarray(nxt)
                for i, r in enumerate(requests):
                    if len(outs[i]) < r.max_new_tokens:
                        outs[i].append(int(arr[i]))
        return [np.asarray(o, np.int32) for o in outs]
