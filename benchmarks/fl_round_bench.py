"""FL round throughput: host loop vs fused (device-resident) scan.

Measures rounds/sec for the same spec executed by the engine's two paths on
the paper's MNIST-MLP analog (synthetic 10-class images, 1-hidden-layer
MLP) and records the result in ``BENCH_fl_rounds.json`` so the fused-path
speedup is a tracked number, not a claim.

Per scheme we record:

* ``host_s`` / ``host_rps``     -- host-loop wall time (jitted
  sub-computations compile on round 1 and are reused, exactly how the
  engine was driven before this benchmark existed);
* ``fused_cold_s``              -- fused path including its one-off whole-
  program compile (what a single cold run pays);
* ``fused_s`` / ``fused_rps``   -- fused path re-run after compilation (the
  steady-state cost of every further run / seed / restart in a sweep);
* ``speedup`` = host_rps-to-fused_rps ratio, plus ``speedup_cold``;
* ``wire_*``                    -- bytes on the wire from a short
  ``wire="audit"`` host run (every payload serialized through
  ``repro.wire`` and reconciled against the BitMeter; the reconcile
  failing aborts the benchmark): total stream bytes, bytes/round,
  payload vs framing split, and message count;
* ``fault_drop``                -- accuracy / total bits / dropout count
  of a short fused run under injected client dropouts at rates
  {0, 0.1, 0.3} (DESIGN.md §8); the rate-0 row must be bit-identical to
  the clean run, so the fault machinery's zero-cost property is a
  benchmarked tripwire, not just a unit test.

The matrix includes an *adaptive* BiCompFL scheme (KL-driven block
allocation): the fused path runs it through bucketed plans selected on
device, and the benchmark **fails hard if that path silently falls back to
the host loop** (every fused run asserts ``out["mode"] == "fused"``), so
CI catches any eligibility regression.  The adaptive host loop re-plans --
and therefore re-traces -- whenever the block count moves, which is exactly
the cost the bucketed fused path removes.  Adaptive-Avg is held to the
same **bitwise** oracle as the static schemes -- its bucket set is exactly
its pow2 plan space.  The Isik-style segment codec (AdaptiveAllocation,
the ``bicompfl-gr-adaptive`` row) runs bucketed-*grid* plans whose fused
trajectory legitimately drifts from the exact-plan host oracle, so it is
held to the documented ``exact_oracle=False`` band instead (bits ratio in
[0.5, 2.0], |final-acc delta| <= 0.15).

Run:  PYTHONPATH=src python -m benchmarks.fl_round_bench [--fast]
      [--rounds N] [--out BENCH_fl_rounds.json]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

from repro.core.blocks import (AdaptiveAllocation, AdaptiveAvgAllocation,
                               FixedAllocation)
from repro.fl import registry
from repro.fl.data import make_synthetic, partition_iid
from repro.fl.engine import FLEngine
from repro.fl.faults import FaultPlan
from repro.fl.nets import make_mlp
from repro.fl.tasks import make_cfl_task, make_mask_task


def build_setup(fast: bool):
    """MNIST-MLP analog: 10 clients, 10x10 synthetic images, width-256 MLP
    (--fast shrinks everything for CI smoke)."""
    hw = 6 if fast else 10
    width = 32 if fast else 256
    n_clients = 4 if fast else 10
    n_train = 240 if fast else 2000
    k = jax.random.PRNGKey(0)
    train, test = make_synthetic(k, n_train=n_train,
                                 n_test=120 if fast else 400, hw=hw, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, n_clients,
                           n_train // n_clients)
    net = make_mlp(in_dim=hw * hw, widths=(width,), signed_constant=True)
    task = make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                          local_epochs=1 if fast else 3,
                          batch_size=40 if fast else 128)
    cnet = make_mlp(in_dim=hw * hw, widths=(width,))
    ctask, theta0 = make_cfl_task(cnet, jax.random.fold_in(k, 3), test.x,
                                  test.y, local_epochs=1 if fast else 3,
                                  batch_size=40 if fast else 128,
                                  local_lr=3e-3)
    return task, ctask, theta0, shards, n_clients


def bench_scheme(name, task, spec_factory, shards, theta0, *, rounds,
                 eval_every, exact_oracle=True):
    res = {}

    engine = FLEngine(task, spec_factory())
    if not engine.fused_supported():  # CI tripwire: no silent host fallback
        raise RuntimeError(f"{name}: fused path not supported -- the "
                           "benchmark would silently measure the host loop")

    def run(mode):
        t0 = time.perf_counter()
        out = FLEngine(task, spec_factory()).run(
            shards, theta0, rounds=rounds, seed=0, eval_every=eval_every,
            mode=mode)
        assert out["mode"] == mode, (name, out["mode"])
        return time.perf_counter() - t0, out

    host_s, host_out = run("host")
    cold_s, _ = run("fused")
    fused_s, fused_out = run("fused")  # warm: whole-run XLA program cached
    if exact_oracle:
        np.testing.assert_array_equal(np.asarray(host_out["theta"]),
                                      np.asarray(fused_out["theta"]))  # oracle
    else:
        # Bucketed-vs-exact plans.  Per-round (same KL profile) the bucket
        # never out-bills the exact plan -- tests/test_allocation.py pins
        # that -- but over a long run the trajectories drift apart and the
        # fused run's KL (hence bits) can land on either side, so the
        # whole-run oracle is a band, not an inequality.
        ratio = fused_out["meter"]["total_bits"] / \
            host_out["meter"]["total_bits"]
        assert 0.5 <= ratio <= 2.0, (name, ratio)
        assert abs(fused_out["final_acc"] - host_out["final_acc"]) <= 0.15, \
            (name, host_out["final_acc"], fused_out["final_acc"])
    res.update(
        host_s=round(host_s, 3), host_rps=round(rounds / host_s, 2),
        fused_cold_s=round(cold_s, 3),
        fused_s=round(fused_s, 3), fused_rps=round(rounds / fused_s, 2),
        speedup=round(host_s / fused_s, 2),
        speedup_cold=round(host_s / cold_s, 2),
        final_acc=host_out["final_acc"])

    # bytes-on-wire: a short wire-audited host run serializes every payload
    # and reconciles booked bits against the stream (divergence raises).
    audit_rounds = min(rounds, 5)
    wire_out = FLEngine(task, spec_factory()).run(
        shards, theta0, rounds=audit_rounds, seed=0,
        eval_every=audit_rounds, mode="host", wire="audit")
    ws = wire_out["wire"]
    res.update(
        wire_rounds=audit_rounds,
        wire_stream_bytes=int(ws["stream_bytes"]),
        wire_bytes_per_round=round(ws["stream_bytes"] / audit_rounds, 1),
        wire_payload_bits=int(ws["payload_bits"]),
        wire_framing_bits=int(ws["framing_bits"]),
        wire_messages=int(ws["messages"]))

    # degraded-run columns: the same scheme under injected client dropouts
    # (DESIGN.md §8).  drop_rate=0 doubles as a tripwire: a trivial
    # FaultPlan must leave the run bit-identical to faults=None.
    fault_rounds = min(rounds, 10)
    fault_cols = {}
    clean = FLEngine(task, spec_factory()).run(
        shards, theta0, rounds=fault_rounds, seed=0,
        eval_every=fault_rounds, mode="fused")
    for rate in (0.0, 0.1, 0.3):
        out = FLEngine(task, spec_factory()).run(
            shards, theta0, rounds=fault_rounds, seed=0,
            eval_every=fault_rounds, mode="fused",
            faults=FaultPlan(drop_rate=rate, seed=0))
        if rate == 0.0:
            assert out["final_acc"] == clean["final_acc"], name
            assert out["meter"] == clean["meter"], name
        key = f"{rate:g}"
        fault_cols[key] = {
            "acc": out["final_acc"],
            "total_bits": out["meter"]["total_bits"],
            "dropped": out["faults"]["summary"]["dropped_total"],
        }
    res["fault_rounds"] = fault_rounds
    res["fault_drop"] = fault_cols

    print(f"{name:18s} host={host_s:7.2f}s ({res['host_rps']:7.1f} r/s)  "
          f"fused={fused_s:7.2f}s ({res['fused_rps']:7.1f} r/s)  "
          f"cold={cold_s:7.2f}s  speedup={res['speedup']:5.2f}x "
          f"(cold {res['speedup_cold']:4.2f}x)  "
          f"wire={res['wire_bytes_per_round']:,.0f}B/round "
          f"({ws['messages']} msgs/{audit_rounds}r)  "
          + " ".join(f"drop{k}={v['acc']:.3f}/"
                     f"{v['total_bits'] / 8e3:,.0f}kB"
                     for k, v in fault_cols.items()), flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="BENCH_fl_rounds.json")
    args = ap.parse_args()
    rounds = args.rounds or (30 if args.fast else 200)
    eval_every = max(rounds // 10, 1)

    task, ctask, theta0, shards, n = build_setup(args.fast)
    d_mask = task.d
    d_cfl = int(theta0.shape[0])
    print(f"== fl_round_bench: {rounds} rounds, {n} clients, "
          f"d_mask={d_mask}, d_cfl={d_cfl}, eval_every={eval_every} ==")

    schemes = {
        "bicompfl-gr": (task, None, True, lambda: registry.bicompfl_spec(
            "GR", allocation=FixedAllocation(128), n_is=64, n_dl=n)),
        # KL-driven allocation: fused == bucketed plans + traced bits; the
        # host loop re-plans (and re-traces) per round -- the slow oracle.
        # Adaptive-Avg's buckets ARE its pow2 plan space (fixed-block codec
        # switched by size), so its oracle stays exact.
        "bicompfl-gr-adaptive-avg": (task, None, True,
                                     lambda: registry.bicompfl_spec(
                                         "GR",
                                         allocation=AdaptiveAvgAllocation(
                                             n_is=64),
                                         n_is=64, n_dl=n)),
        # Isik-style segment codec: on the tracked matrix since the Pallas
        # segment-logW kernel made its weight evaluation a real lever (on
        # CPU the jnp route runs; segment_logw_pallas=True switches it on a
        # TPU backend).  The fused path runs bucketed-*grid* plans whose
        # trajectory legitimately drifts from the exact-plan host oracle,
        # so it is held to the documented band, not the bitwise oracle.
        "bicompfl-gr-adaptive": (task, None, False,
                                 lambda: registry.bicompfl_spec(
                                     "GR",
                                     allocation=AdaptiveAllocation(n_is=64),
                                     n_is=64, n_dl=n)),
        "fedavg": (ctask, theta0, True, lambda: registry.baseline_spec(
            "fedavg", n=n, d=d_cfl)),
    }
    results = {}
    for name, (t, th0, exact, factory) in schemes.items():
        results[name] = bench_scheme(name, t, factory, shards, th0,
                                     rounds=rounds, eval_every=eval_every,
                                     exact_oracle=exact)
        jax.clear_caches()

    payload = {
        "config": {"rounds": rounds, "n_clients": n, "d_mask": d_mask,
                   "d_cfl": d_cfl, "eval_every": eval_every,
                   "fast": args.fast, "machine": platform.machine(),
                   "backend": jax.default_backend()},
        "schemes": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
