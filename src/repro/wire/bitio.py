"""MSB-first bit packing: the lowest layer of the wire format.

``BitWriter`` packs integer fields of arbitrary bit width into a byte
stream, most-significant bit first (network bit order), so a field of
width w always occupies exactly w bits regardless of byte boundaries.
``BitReader`` is its exact inverse.  Floats cross the wire as IEEE-754
big-endian bit patterns (``write_f32`` / ``read_f32``): the round-trip is
bit-exact by construction, never a decimal detour.

Both ends count bits (``bits_written`` / ``bits_read``) so codecs can be
audited against :class:`repro.core.bitmeter.BitMeter` bookings, and both
support byte alignment (``align``) for framing payload boundaries.

Error taxonomy (all subclasses of :class:`WireError`, itself a ValueError
so pre-existing ``except ValueError`` call sites keep working):

* :class:`WireFormatError`   -- structurally malformed data: bad magic,
  overrunning reads (truncation), nonzero padding, out-of-contract widths;
* :class:`WireIntegrityError` -- structurally sound but corrupted in
  flight: the frame CRC32 trailer does not match the received bytes.

Anything raised while parsing wire bytes is a ``WireError`` -- never a bare
``IndexError`` or struct noise -- so retry loops can catch one type.
"""
from __future__ import annotations

import zlib

import numpy as np


class WireError(ValueError):
    """Base class: anything wrong with data on (or for) the wire."""


class WireFormatError(WireError):
    """Malformed or out-of-contract wire data (loud by design)."""


class WireIntegrityError(WireError):
    """Frame failed its CRC32 integrity check: corrupted in flight."""


class BitWriter:
    """Accumulates an MSB-first bit stream."""

    def __init__(self):
        self._bytes = bytearray()
        self._acc = 0       # bit accumulator, MSB side filled first
        self._nacc = 0      # bits currently in the accumulator

    @property
    def bits_written(self) -> int:
        return 8 * len(self._bytes) + self._nacc

    def write(self, value: int, width: int) -> None:
        """Write ``value`` as an unsigned ``width``-bit field."""
        value = int(value)
        width = int(width)
        if width < 0:
            raise WireFormatError(f"negative width {width}")
        if width == 0:
            if value != 0:
                raise WireFormatError(f"value {value} in zero-width field")
            return
        if value < 0 or value >> width:
            raise WireFormatError(
                f"value {value} does not fit in {width} bits")
        self._acc = (self._acc << width) | value
        self._nacc += width
        while self._nacc >= 8:
            self._nacc -= 8
            self._bytes.append((self._acc >> self._nacc) & 0xFF)
        self._acc &= (1 << self._nacc) - 1

    def write_f32(self, x) -> None:
        """Write one float32 as its big-endian IEEE-754 bit pattern."""
        self.write(int(np.float32(x).view(np.uint32)), 32)

    def write_f32_array(self, xs) -> None:
        arr = np.asarray(xs, dtype=np.float32).reshape(-1)
        if self._nacc == 0:  # byte-aligned: bulk big-endian append
            self._bytes.extend(arr.astype(">f4").tobytes())
            return
        for u in arr.view(np.uint32):
            self.write(int(u), 32)

    def write_bits(self, data: bytes, nbits: int) -> None:
        """Splice ``nbits`` MSB-first bits from ``data`` (relay payloads)."""
        if nbits > 8 * len(data):
            raise WireFormatError(
                f"asked for {nbits} bits from {len(data)} bytes")
        full, rem = divmod(int(nbits), 8)
        if self._nacc == 0:  # byte-aligned: bulk append of the whole bytes
            self._bytes.extend(data[:full])
        else:
            for b in data[:full]:
                self.write(b, 8)
        if rem:
            self.write(data[full] >> (8 - rem), rem)

    def align(self) -> int:
        """Zero-pad to the next byte boundary; returns the pad width (< 8)."""
        pad = (-self._nacc) % 8
        if pad:
            self.write(0, pad)
        return pad

    @property
    def byte_offset(self) -> int:
        """Current write position in whole bytes (must be byte-aligned)."""
        if self._nacc:
            raise WireFormatError(
                f"byte_offset taken mid-byte ({self._nacc} pending bits)")
        return len(self._bytes)

    def crc32(self, start_byte: int) -> int:
        """CRC32 of the bytes written since ``start_byte`` (aligned span)."""
        if self._nacc:
            raise WireFormatError(
                f"crc32 taken mid-byte ({self._nacc} pending bits)")
        return zlib.crc32(memoryview(self._bytes)[start_byte:]) & 0xFFFFFFFF

    def getvalue(self) -> bytes:
        """The stream so far, zero-padded to whole bytes (non-destructive)."""
        out = bytearray(self._bytes)
        if self._nacc:
            out.append((self._acc << (8 - self._nacc)) & 0xFF)
        return bytes(out)


class BitReader:
    """Reads an MSB-first bit stream produced by :class:`BitWriter`."""

    def __init__(self, data: bytes, nbits: int | None = None):
        self._data = bytes(data)
        self._nbits = 8 * len(self._data) if nbits is None else int(nbits)
        if self._nbits > 8 * len(self._data):
            raise WireFormatError(
                f"{self._nbits} bits promised but only "
                f"{len(self._data)} bytes present")
        self._pos = 0  # bit cursor

    @property
    def bits_read(self) -> int:
        return self._pos

    @property
    def bits_left(self) -> int:
        return self._nbits - self._pos

    def read(self, width: int) -> int:
        width = int(width)
        if width < 0:
            raise WireFormatError(f"negative width {width}")
        if width == 0:
            return 0
        if self._pos + width > self._nbits:
            raise WireFormatError(
                f"read of {width} bits overruns stream "
                f"({self.bits_left} left)")
        out = 0
        pos = self._pos
        remaining = width
        while remaining:
            byte = self._data[pos >> 3]
            offset = pos & 7
            take = min(8 - offset, remaining)
            chunk = (byte >> (8 - offset - take)) & ((1 << take) - 1)
            out = (out << take) | chunk
            pos += take
            remaining -= take
        self._pos = pos
        return out

    def read_f32(self) -> np.float32:
        return np.uint32(self.read(32)).view(np.float32)

    def read_f32_array(self, n: int) -> np.ndarray:
        if self._pos % 8 == 0 and self._pos + 32 * n <= self._nbits:
            start = self._pos >> 3  # byte-aligned: bulk big-endian view
            self._pos += 32 * n
            return np.frombuffer(self._data, dtype=">f4", count=n,
                                 offset=start).astype(np.float32)
        out = np.empty(n, dtype=np.uint32)
        for i in range(n):
            out[i] = self.read(32)
        return out.view(np.float32)

    def read_payload(self, nbits: int) -> tuple:
        """Extract ``nbits`` as a standalone ``(bytes, nbits)`` sub-stream."""
        nbits = int(nbits)
        if self._pos % 8 == 0:  # byte-aligned: bulk byte slice
            if self._pos + nbits > self._nbits:
                raise WireFormatError(
                    f"read of {nbits} bits overruns stream "
                    f"({self.bits_left} left)")
            start = self._pos >> 3
            nbytes = -(-nbits // 8)
            chunk = bytearray(self._data[start:start + nbytes])
            if nbits % 8:  # zero the trailing pad bits of the last byte
                chunk[-1] &= 0xFF << (8 - nbits % 8) & 0xFF
            self._pos += nbits
            return bytes(chunk), nbits
        w = BitWriter()
        full, rem = divmod(nbits, 8)
        for _ in range(full):
            w.write(self.read(8), 8)
        if rem:
            w.write(self.read(rem), rem)
        return w.getvalue(), nbits

    def align(self) -> None:
        pad = (-self._pos) % 8
        if pad and self.read(pad) != 0:
            raise WireFormatError("nonzero alignment padding")

    def crc32(self, start_byte: int, end_byte: int) -> int:
        """CRC32 of the underlying bytes in ``[start_byte, end_byte)``."""
        if not 0 <= start_byte <= end_byte <= len(self._data):
            raise WireFormatError(
                f"crc32 span [{start_byte}, {end_byte}) outside "
                f"{len(self._data)}-byte stream")
        return zlib.crc32(memoryview(self._data)[start_byte:end_byte]) \
            & 0xFFFFFFFF

    def expect_exhausted(self) -> None:
        if self.bits_left:
            raise WireFormatError(f"{self.bits_left} unread bits left")
