"""Minimal pure-JAX optimizers (pytree-generic): sgd, momentum, adam.

Each optimizer is a pair (init_fn, update_fn):
    state  = init_fn(params)
    params, state = update_fn(grads, params, state)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, params, state):
        new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, params, vel):
        vel = jax.tree.map(lambda v, g: beta * v + g, vel, grads)
        new = jax.tree.map(lambda p, v: p - lr * v, params, vel)
        return new, vel

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = jax.tree.map(jnp.zeros_like, params)
        return AdamState(mu=z, nu=jax.tree.map(jnp.zeros_like, params), step=jnp.zeros((), jnp.int32))

    def update(grads, params, state):
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        new = jax.tree.map(
            lambda p, m, v: p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps), params, mu, nu
        )
        return new, AdamState(mu=mu, nu=nu, step=step)

    return Optimizer(init, update)


def adafactor_like(lr: float, eps: float = 1e-30) -> Optimizer:
    """Memory-lean second-moment-factored optimizer for huge-model training.

    Keeps row/col second-moment factors for matrices (>=2D leaves) and full
    second moments for vectors -- the standard trick to train trillion-scale
    MoE where Adam's f32 (m, v) would not fit HBM.
    """
    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return (jnp.zeros(p.shape[:-1], jnp.float32), jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
            return jnp.zeros_like(p, dtype=jnp.float32)

        return jax.tree.map(leaf, params)

    def update(grads, params, state):
        def leaf(p, g, s):
            g = g.astype(jnp.float32)
            if p.ndim >= 2:
                r, c = s
                r = 0.999 * r + 0.001 * jnp.mean(g * g, axis=-1)
                c = 0.999 * c + 0.001 * jnp.mean(g * g, axis=-2)
                denom = jnp.sqrt(
                    r[..., :, None] * c[..., None, :] / (jnp.mean(r, axis=-1)[..., None, None] + eps) + eps
                )
                upd = g / denom
                return (p - lr * upd).astype(p.dtype), (r, c)
            v = 0.999 * s + 0.001 * g * g
            return (p - lr * g / (jnp.sqrt(v) + 1e-8)).astype(p.dtype), v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state)
        out = [leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_s = tdef.unflatten([o[1] for o in out])
        return new_p, new_s

    return Optimizer(init, update)
