"""Checkpoint robustness: atomic writes, self-describing load, corrupt-file
skipping, and the per-step directory protocol the FL engine's
``resume_from=`` builds on (DESIGN.md §8, "Crash-safe resume")."""
import os
import struct

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree():
    return {
        "theta": np.arange(6, dtype=np.float32).reshape(2, 3),
        "nested": {"b": np.float64(2.5), "a": np.int32(7)},
        "seq": [np.ones(2, np.float32), (np.zeros((), np.int64), None)],
    }


def _assert_tree_equal(a, b):
    if isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            _assert_tree_equal(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            _assert_tree_equal(x, y)
    elif a is None:
        assert b is None
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSelfDescribingLoad:

    def test_roundtrip_without_reference_tree(self, tmp_path):
        path = str(tmp_path / "c.repro")
        ckpt.save(path, _tree(), step=3)
        tree, step = ckpt.load(path)
        assert step == 3
        _assert_tree_equal(tree, _tree())

    def test_non_alphabetical_dict_keys_rebuild_unscrambled(self, tmp_path):
        """jax.tree.leaves flattens dicts sorted by key; a descriptor
        emitted in insertion order would rebuild ``z``/``a`` swapped."""
        path = str(tmp_path / "c.repro")
        src = {"z": np.full(3, 1.0, np.float32),
               "a": np.full(3, 2.0, np.float32)}
        ckpt.save(path, src)
        tree, _ = ckpt.load(path)
        _assert_tree_equal(tree, src)

    def test_scalar_bit_exact(self, tmp_path):
        path = str(tmp_path / "c.repro")
        ckpt.save(path, {"x": 0.1, "n": 123456789})
        tree, _ = ckpt.load(path)
        assert float(tree["x"]) == 0.1 and int(tree["n"]) == 123456789

    def test_jax_arrays_roundtrip(self, tmp_path):
        path = str(tmp_path / "c.repro")
        src = {"w": jnp.linspace(0, 1, 7, dtype=jnp.float32)}
        ckpt.save(path, src)
        tree, _ = ckpt.load(path)
        np.testing.assert_array_equal(np.asarray(tree["w"]),
                                      np.asarray(src["w"]))


class TestAtomicSave:

    def test_no_temp_residue(self, tmp_path):
        path = str(tmp_path / "c.repro")
        ckpt.save(path, _tree())
        assert os.listdir(tmp_path) == ["c.repro"]

    def test_overwrite_is_replace_not_append(self, tmp_path):
        path = str(tmp_path / "c.repro")
        ckpt.save(path, {"a": np.zeros(1000, np.float64)})
        big = os.path.getsize(path)
        ckpt.save(path, {"a": np.zeros(1, np.float64)})
        assert os.path.getsize(path) < big
        tree, _ = ckpt.load(path)
        assert tree["a"].shape == (1,)

    def test_creates_parent_directory(self, tmp_path):
        path = str(tmp_path / "deep" / "er" / "c.repro")
        ckpt.save(path, _tree())
        assert ckpt.validate(path)[0]


class TestCorruptionHandling:

    def _saved(self, tmp_path, step=5):
        path = str(tmp_path / "c.repro")
        ckpt.save(path, _tree(), step=step)
        return path

    def test_validate_ok(self, tmp_path):
        ok, step, reason = ckpt.validate(self._saved(tmp_path))
        assert ok and step == 5 and reason == ""

    def test_bad_magic(self, tmp_path):
        path = self._saved(tmp_path)
        data = open(path, "rb").read()
        open(path, "wb").write(b"NOTACKPT??" + data[10:])
        ok, _, reason = ckpt.validate(path)
        assert not ok and "magic" in reason
        with pytest.raises(ckpt.CheckpointError):
            ckpt.load(path)

    def test_truncated_payload(self, tmp_path):
        path = self._saved(tmp_path)
        data = open(path, "rb").read()
        open(path, "wb").write(data[:-4])
        ok, step, reason = ckpt.validate(path)
        assert not ok and "truncated" in reason and step == 5
        with pytest.raises(ckpt.CheckpointError):
            ckpt.load(path)

    def test_garbled_header(self, tmp_path):
        path = self._saved(tmp_path)
        data = bytearray(open(path, "rb").read())
        hdr_at = len(ckpt.MAGIC) + 8
        data[hdr_at] ^= 0xFF  # breaks the JSON
        open(path, "wb").write(bytes(data))
        ok, _, reason = ckpt.validate(path)
        assert not ok

    def test_latest_step_warns_and_skips_corrupt(self, tmp_path):
        path = self._saved(tmp_path)
        open(path, "wb").write(b"garbage")
        with pytest.warns(RuntimeWarning, match="corrupt"):
            assert ckpt.latest_step(path) is None

    def test_latest_step_missing_file_is_quietly_none(self, tmp_path):
        assert ckpt.latest_step(str(tmp_path / "absent.repro")) is None


class TestStepDirectory:

    def test_latest_picks_newest_valid(self, tmp_path):
        d = str(tmp_path)
        for s in (2, 4, 6):
            ckpt.save_step(d, {"s": np.int64(s)}, s)
        path, step = ckpt.latest(d)
        assert step == 6 and path == ckpt.step_path(d, 6)
        tree, hdr_step = ckpt.load(path)
        assert int(tree["s"]) == 6 and hdr_step == 6

    def test_latest_skips_torn_newest(self, tmp_path):
        """A crash mid-write of step 6 must fall back to step 4."""
        d = str(tmp_path)
        for s in (2, 4, 6):
            ckpt.save_step(d, {"s": np.zeros(64, np.float64)}, s)
        p6 = ckpt.step_path(d, 6)
        data = open(p6, "rb").read()
        open(p6, "wb").write(data[: len(data) // 2])
        with pytest.warns(RuntimeWarning, match="corrupt"):
            path, step = ckpt.latest(d)
        assert step == 4 and path == ckpt.step_path(d, 4)

    def test_empty_or_missing_directory(self, tmp_path):
        assert ckpt.latest(str(tmp_path)) == (None, None)
        assert ckpt.latest(str(tmp_path / "nope")) == (None, None)

    def test_foreign_files_ignored(self, tmp_path):
        d = str(tmp_path)
        ckpt.save_step(d, {"s": np.int64(1)}, 1)
        open(os.path.join(d, "notes.txt"), "w").write("hi")
        open(os.path.join(d, "ckpt_zzz.tmp"), "w").write("partial")
        path, step = ckpt.latest(d)
        assert step == 1
