"""Model assembly: layer plans -> scanned parameter stacks -> train/serve fns.

The layer sequence of every assigned architecture is *periodic* (possibly
after a short prefix -- e.g. Kimi K2's first dense layer):

    plans = [plan(0), ..., plan(L-1)],  plan = (mixer_kind, ffn_kind)

``plan_groups`` factors it into (prefix, pattern, n_rep); parameters of the
``n_rep`` repetitions are *stacked* (leading dim n_rep) and iterated with
``lax.scan`` -- the compiled HLO contains one body per distinct plan, which
keeps 512-device compiles tractable and mirrors MaxText's scanned-layers
practice.  ``remat`` wraps the scan body (full activation rematerialisation).

Modality handling (the one sanctioned stub):
* audio (hubert):   inputs are precomputed frame embeddings (B, S, d);
* vlm (qwen2-vl):   token ids + image patch embeddings (B, n_img, d) that
  overwrite the first n_img token slots; M-RoPE takes (B, S, 3) positions.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import mamba as mamba_mod
from . import moe as moe_mod
from . import rwkv6 as rwkv_mod
from . import sharding
from .config import ArchConfig
from .layers import attention, decode_attention, dtype_of, init_attn, init_ffn, ffn, rmsnorm


# ---------------------------------------------------------------------------
# Layer plans -> (prefix, pattern, n_rep)
# ---------------------------------------------------------------------------


def plan_groups(cfg: ArchConfig) -> Tuple[List, List, int]:
    plans = [cfg.layer_plan(i) for i in range(cfg.n_layers)]
    # strip a non-repeating prefix (leading dense layers of MoE stacks)
    prefix_len = 0
    if cfg.moe and cfg.first_dense_layers:
        prefix_len = cfg.first_dense_layers
    prefix, rest = plans[:prefix_len], plans[prefix_len:]
    for p in range(1, len(rest) + 1):
        if len(rest) % p == 0 and rest == rest[:p] * (len(rest) // p):
            return prefix, rest[:p], len(rest) // p
    return prefix, rest, 1


# ---------------------------------------------------------------------------
# Single-layer init / apply
# ---------------------------------------------------------------------------


def init_layer(key: jax.Array, cfg: ArchConfig, plan) -> Tuple[Dict, Dict]:
    mixer, ffn_kind = plan
    d = cfg.d_model
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    params: Dict[str, Any] = {"ln1": jnp.zeros((d,), dt)}
    specs: Dict[str, Any] = {"ln1": P(None)}

    if mixer == "attn":
        params["mixer"], specs["mixer"] = init_attn(k1, cfg)
    elif mixer == "mamba":
        params["mixer"], specs["mixer"] = mamba_mod.init_mamba(k1, cfg)
    elif mixer == "rwkv6":
        params["mixer"], specs["mixer"] = rwkv_mod.init_rwkv(k1, cfg)
    else:
        raise ValueError(mixer)

    if ffn_kind != "rwkv_ffn":  # rwkv channel-mix lives inside its mixer params
        params["ln2"] = jnp.zeros((d,), dt)
        specs["ln2"] = P(None)
        if ffn_kind == "dense":
            params["ffn"], specs["ffn"] = init_ffn(k2, cfg)
        elif ffn_kind == "moe":
            params["ffn"], specs["ffn"] = moe_mod.init_moe(k2, cfg)
        else:
            raise ValueError(ffn_kind)
    return params, specs


def apply_layer(cfg: ArchConfig, plan, params, x: jax.Array, positions,
                *, kv_chunk: int = 1024):
    """Training / prefill layer.  Returns (x, aux_loss)."""
    mixer, ffn_kind = plan
    aux = jnp.zeros((), jnp.float32)
    if mixer == "attn":
        x = x + attention(cfg, params["mixer"], rmsnorm(x, params["ln1"]),
                          positions, kv_chunk=kv_chunk)
    elif mixer == "mamba":
        st0 = mamba_mod.init_mamba_state(cfg, x.shape[0], x.dtype)
        y, _ = mamba_mod.mamba_block(cfg, params["mixer"], rmsnorm(x, params["ln1"]), st0)
        x = x + y
    elif mixer == "rwkv6":
        st0 = rwkv_mod.init_rwkv_state(cfg, x.shape[0], x.dtype)
        y, st1 = rwkv_mod.time_mix_chunk(cfg, params["mixer"], rmsnorm(x, params["ln1"]), st0)
        x = x + y
        y, _ = rwkv_mod.channel_mix(cfg, params["mixer"], rmsnorm(x, params["ln2_rwkv"]), st1)
        return x + y, aux

    if ffn_kind == "dense":
        x = x + ffn(params["ffn"], rmsnorm(x, params["ln2"]))
    elif ffn_kind == "moe":
        y, aux = moe_mod.moe_ffn(cfg, params["ffn"], rmsnorm(x, params["ln2"]))
        x = x + y
    return x, aux


def decode_layer(cfg: ArchConfig, plan, params, x: jax.Array, pos,
                 cache):
    """One-token decode layer.  Returns (x, new_cache)."""
    mixer, ffn_kind = plan
    if mixer == "attn":
        y, cache = decode_attention(cfg, params["mixer"], rmsnorm(x, params["ln1"]),
                                    pos, cache)
        x = x + y
    elif mixer == "mamba":
        y, cache = mamba_mod.decode_step(cfg, params["mixer"], rmsnorm(x, params["ln1"]), cache)
        x = x + y
    elif mixer == "rwkv6":
        y, cache = rwkv_mod.decode_step(cfg, params["mixer"], rmsnorm(x, params["ln1"]), cache)
        x = x + y
        y, cache = rwkv_mod.decode_channel_mix(
            cfg, params["mixer"], rmsnorm(x, params["ln2_rwkv"]), cache)
        return x + y, cache

    if ffn_kind == "dense":
        x = x + ffn(params["ffn"], rmsnorm(x, params["ln2"]))
    elif ffn_kind == "moe":
        y, _ = moe_mod.moe_ffn(cfg, params["ffn"], rmsnorm(x, params["ln2"]))
        x = x + y
    return x, cache


# rwkv needs a second norm param that is not gated behind ffn_kind
def _patch_rwkv_lns(cfg: ArchConfig, params: Dict, specs: Dict, plan):
    if plan[0] == "rwkv6":
        params["ln2_rwkv"] = jnp.zeros((cfg.d_model,), dtype_of(cfg))
        specs["ln2_rwkv"] = P(None)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


class Model(NamedTuple):
    cfg: ArchConfig
    prefix: List        # list of plans
    pattern: List       # repeating unit of plans
    n_rep: int


def build(cfg: ArchConfig) -> Model:
    prefix, pattern, n_rep = plan_groups(cfg)
    return Model(cfg=cfg, prefix=prefix, pattern=pattern, n_rep=n_rep)


def init_params(model: Model, key: jax.Array) -> Tuple[Dict, Dict]:
    cfg = model.cfg
    d, v = cfg.d_model, cfg.vocab
    dt = dtype_of(cfg)
    keys = jax.random.split(key, 4 + len(model.prefix))
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}

    if cfg.embed_inputs:
        params["embed"] = (jax.random.normal(keys[0], (v, d)) * d ** -0.5).astype(dt)
        specs["embed"] = P("model", None)

    # prefix layers (unstacked)
    pre_p, pre_s = [], []
    for i, plan in enumerate(model.prefix):
        p, s = init_layer(keys[4 + i], cfg, plan)
        _patch_rwkv_lns(cfg, p, s, plan)
        pre_p.append(p)
        pre_s.append(s)
    params["prefix"] = pre_p
    specs["prefix"] = pre_s

    # pattern layers, stacked over n_rep
    pat_p, pat_s = [], []
    for j, plan in enumerate(model.pattern):
        def one(k, plan=plan):
            p, s = init_layer(k, cfg, plan)
            _patch_rwkv_lns(cfg, p, s, plan)
            return p
        ks = jax.random.split(jax.random.fold_in(keys[1], j), model.n_rep)
        stacked = jax.vmap(one)(ks)
        p0, s0 = init_layer(jax.random.fold_in(keys[1], j), cfg, plan)
        _patch_rwkv_lns(cfg, p0, s0, plan)
        sspec = jax.tree.map(lambda sp: P(None, *sp), s0,
                             is_leaf=lambda t: isinstance(t, P))
        pat_p.append(stacked)
        pat_s.append(sspec)
    params["pattern"] = pat_p
    specs["pattern"] = pat_s

    params["final_norm"] = jnp.zeros((d,), dt)
    specs["final_norm"] = P(None)
    params["head"] = (jax.random.normal(keys[2], (d, v)) * d ** -0.5).astype(dt)
    specs["head"] = P(None, "model")
    return params, specs


def abstract_init(model: Model):
    """(params ShapeDtypeStructs, specs) without allocating anything.

    Specs are plain Python metadata created during tracing, so they can be
    captured by side effect under ``jax.eval_shape``.
    """
    box = {}

    def f(key):
        p, s = init_params(model, key)
        box["specs"] = s
        return p

    sds = jax.eval_shape(f, jax.random.PRNGKey(0))
    return sds, box["specs"]


def fsdp_specs(params, specs, *, min_size: int = 2 ** 16):
    """ZeRO-3 refinement: shard one replicated dim of each large leaf on ``data``.

    Picks the largest dim that is currently None and divides the data-axis
    size; leaves small leaves (norms, biases) replicated.
    """
    data = sharding.axis_size("data")
    if data <= 1:
        return specs

    def refine(leaf, spec):
        if not isinstance(spec, P) or leaf.size < min_size:
            return spec
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        if "data" in entries:
            return spec
        cands = [i for i, (ax, n) in enumerate(zip(entries, leaf.shape))
                 if ax is None and n % data == 0]
        if not cands:
            return spec
        best = max(cands, key=lambda i: leaf.shape[i])
        entries[best] = "data"
        return P(*entries)

    # P is a tuple subclass => jax.tree would descend into it; flatten the
    # spec tree with an explicit is_leaf and zip against the param leaves.
    flat_specs, sdef = jax.tree.flatten(specs, is_leaf=lambda t: isinstance(t, P))
    flat_params = jax.tree.leaves(params)
    assert len(flat_specs) == len(flat_params)
    return jax.tree.unflatten(sdef, [refine(l, s) for l, s in zip(flat_params, flat_specs)])


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def embed_inputs(model: Model, params, batch: Dict[str, jax.Array]) -> jax.Array:
    cfg = model.cfg
    if not cfg.embed_inputs:                      # audio: frame embeddings
        x = batch["inputs"].astype(dtype_of(cfg))
    else:
        tok = batch["tokens"]
        x = jnp.take(params["embed"], tok, axis=0)
        if cfg.vlm_image_tokens and "image_embeds" in batch:
            img = batch["image_embeds"].astype(x.dtype)   # (B, n_img, d)
            n_img = img.shape[1]
            x = jnp.concatenate([img, x[:, n_img:]], axis=1)
    return sharding.constraint(x, P(sharding.batch_axes(), None, None))


def positions_for(model: Model, batch: Dict[str, jax.Array], s: int) -> jax.Array:
    cfg = model.cfg
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.arange(s)[None]
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(pos[..., None], (1, s, 3))
    return pos


def forward(model: Model, params, batch: Dict[str, jax.Array],
            *, kv_chunk: int = 1024):
    """Returns (logits_bf16 (B,S,V) vocab-sharded, aux_loss)."""
    cfg = model.cfg
    x = embed_inputs(model, params, batch)
    s = x.shape[1]
    positions = positions_for(model, batch, s)
    aux_total = jnp.zeros((), jnp.float32)

    for plan, p in zip(model.prefix, params["prefix"]):
        x, aux = apply_layer(cfg, plan, p, x, positions, kv_chunk=kv_chunk)
        aux_total += aux

    for plan, stacked in zip(model.pattern, params["pattern"]):
        def body(carry, layer_params, plan=plan):
            xx, aa = carry
            xx, aux = apply_layer(cfg, plan, layer_params, xx, positions,
                                  kv_chunk=kv_chunk)
            return (xx, aa + aux), ()
        if cfg.remat:
            policy = None
            if cfg.remat_policy == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            body = jax.checkpoint(body, policy=policy)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)

    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["head"]
    logits = sharding.constraint(logits, P(sharding.batch_axes(), None, "model"))
    return logits, aux_total


def lm_loss(model: Model, params, batch: Dict[str, jax.Array],
            *, aux_weight: float = 0.01, kv_chunk: int = 1024) -> jax.Array:
    logits, aux = forward(model, params, batch, kv_chunk=kv_chunk)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux_weight * aux


def encoder_loss(model: Model, params, batch: Dict[str, jax.Array],
                 *, kv_chunk: int = 1024) -> jax.Array:
    """Frame-classification CE for the encoder-only (audio) arch."""
    return lm_loss(model, params, batch, aux_weight=0.0, kv_chunk=kv_chunk)


def prefill_step(model: Model, params, batch: Dict[str, jax.Array],
                 *, kv_chunk: int = 1024) -> jax.Array:
    """Serving prefill: full forward, last-position logits only (B, 1, V).

    (The dry-run elides the KV-cache write; the backbone compute -- the
    roofline-relevant part -- is identical.)
    """
    cfg = model.cfg
    x = embed_inputs(model, params, batch)
    s = x.shape[1]
    positions = positions_for(model, batch, s)

    for plan, p in zip(model.prefix, params["prefix"]):
        x, _ = apply_layer(cfg, plan, p, x, positions, kv_chunk=kv_chunk)

    for plan, stacked in zip(model.pattern, params["pattern"]):
        def body(xx, layer_params, plan=plan):
            xx, _ = apply_layer(cfg, plan, layer_params, xx, positions,
                                kv_chunk=kv_chunk)
            return xx, ()
        x, _ = jax.lax.scan(body, x, stacked)

    x = rmsnorm(x[:, -1:], params["final_norm"])
    logits = x @ params["head"]
    return sharding.constraint(logits, P(sharding.batch_axes(), None, "model"))


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def init_cache_entry(cfg: ArchConfig, plan, batch: int, s_max: int):
    mixer = plan[0]
    dt = dtype_of(cfg)
    if mixer == "attn":
        s_alloc = min(s_max, cfg.sliding_window) if cfg.sliding_window else s_max
        shape = (batch, s_alloc, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_cache_quant:
            from .layers import _kv_groups
            sshape = shape[:-1] + (cfg.head_dim // _kv_groups(cfg.head_dim),)
            return (jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                    jnp.zeros(sshape, jnp.float16), jnp.zeros(sshape, jnp.float16))
        return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))
    if mixer == "mamba":
        return mamba_mod.init_mamba_state(cfg, batch, dt)
    if mixer == "rwkv6":
        return rwkv_mod.init_rwkv_state(cfg, batch, dt)
    raise ValueError(mixer)


def cache_entry_spec(cfg: ArchConfig, plan, *, batch: int = 0):
    """Cache sharding for one layer.

    Default: batch over (pod, data), kv heads / head_dim over model.  When
    the batch does not divide the data axes (the batch-1 long-context
    shape), the KV *sequence* dim is sharded over data instead -- the
    sequence-parallel cache layout.
    """
    from .layers import kv_head_spec
    mixer = plan[0]
    bspec = sharding.batch_axes()
    data = sharding.axis_size("data") * sharding.axis_size("pod")
    seq_parallel = batch > 0 and batch % max(data, 1) != 0
    if seq_parallel:
        bspec = None
    if mixer == "attn":
        hs = kv_head_spec(cfg, sharding.axis_size("model"), for_cache=True)
        sp = P(bspec, "data" if seq_parallel else None, *hs)
        if cfg.kv_cache_quant:
            ssp = P(bspec, "data" if seq_parallel else None, hs[0], None)
            return (sp, sp, ssp, ssp)
        return (sp, sp)
    if mixer == "mamba":
        return mamba_mod.MambaState(conv=P(bspec, None, "model"),
                                    ssm=P(bspec, "model", None))
    if mixer == "rwkv6":
        return rwkv_mod.RWKVState(s=P(bspec, "model", None, None),
                                  x_prev_tm=P(bspec, None),
                                  x_prev_cm=P(bspec, None))
    raise ValueError(mixer)


def init_cache(model: Model, batch: int, s_max: int):
    cfg = model.cfg
    cache = {
        "prefix": [init_cache_entry(cfg, plan, batch, s_max) for plan in model.prefix],
        "pattern": [
            jax.tree.map(lambda x: jnp.broadcast_to(x[None], (model.n_rep,) + x.shape),
                         init_cache_entry(cfg, plan, batch, s_max))
            for plan in model.pattern
        ],
    }
    return cache


def cache_specs(model: Model, *, batch: int = 0):
    cfg = model.cfg
    return {
        "prefix": [cache_entry_spec(cfg, plan, batch=batch) for plan in model.prefix],
        "pattern": [
            jax.tree.map(lambda sp: P(None, *sp),
                         cache_entry_spec(cfg, plan, batch=batch),
                         is_leaf=lambda t: isinstance(t, P))
            for plan in model.pattern
        ],
    }


def serve_step(model: Model, params, cache, tokens: jax.Array, pos):
    """One decode step: tokens (B, 1) -> (logits (B, 1, V), new_cache).

    ``pos`` is the current absolute position (scalar int32) == tokens so far.
    """
    cfg = model.cfg
    if cfg.embed_inputs:
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        raise ValueError("encoder-only archs have no decode step")
    x = sharding.constraint(x, P(sharding.batch_axes(), None, None))

    new_prefix = []
    for plan, p, c in zip(model.prefix, params["prefix"], cache["prefix"]):
        x, c = decode_layer(cfg, plan, p, x, pos, c)
        new_prefix.append(c)

    new_pattern = []
    for plan, stacked, c in zip(model.pattern, params["pattern"], cache["pattern"]):
        def body(xx, scanned, plan=plan):
            layer_params, layer_cache = scanned
            xx, new_c = decode_layer(cfg, plan, layer_params, xx, pos, layer_cache)
            return xx, new_c
        x, new_c = jax.lax.scan(body, x, (stacked, c))
        new_pattern.append(new_c)

    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["head"]
    logits = sharding.constraint(logits, P(sharding.batch_axes(), None, "model"))
    return logits, {"prefix": new_prefix, "pattern": new_pattern}
