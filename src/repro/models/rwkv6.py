"""RWKV-6 "Finch" block: data-dependent-decay linear attention + channel mix.

Per head h with key/value dim Dh, the time-mix recurrence over tokens t is

    S_t = diag(w_t) S_{t-1} + k_t v_t^T            (state S: (Dh, Dh))
    o_t = r_t^T (diag(u) k_t v_t^T + S_{t-1})

with data-dependent decay w_t = exp(-exp(dd_t)) produced by a LoRA-style
two-layer projection of the token (the Finch novelty), and a learned bonus u
for the current token.  Token-shift interpolation (lerp between x_t and
x_{t-1} with learned + data-dependent mix) feeds the r/k/v/w/g projections.

Training runs the recurrence with ``lax.scan`` over time (one HLO while
loop -- compile-friendly at any depth); decode carries (S, x_prev) as
explicit state -- O(1) per token, which is what makes the 500k-context
shape runnable.

Sharding: heads over ``model``; FFN hidden over ``model``.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import sharding
from .config import ArchConfig
from .layers import dtype_of

DECAY_LORA = 64


class RWKVState(NamedTuple):
    s: jax.Array        # (B, H, Dh, Dh) time-mix matrix state
    x_prev_tm: jax.Array  # (B, d) previous token input (time-mix shift)
    x_prev_cm: jax.Array  # (B, d) previous token input (channel-mix shift)


def head_layout(cfg: ArchConfig) -> Tuple[int, int]:
    """(n_heads, head_dim) for the RWKV time-mix (64-dim heads)."""
    dh = 64
    return cfg.d_model // dh, dh


def init_rwkv(key: jax.Array, cfg: ArchConfig):
    d = cfg.d_model
    h, dh = head_layout(cfg)
    ks = jax.random.split(key, 12)
    dt = dtype_of(cfg)
    std = d ** -0.5
    params = {
        # token-shift mix coefficients (r, k, v, w, g) + channel-mix (k)
        "mu": jnp.full((5, d), 0.5, dt),
        "mu_cm": jnp.full((1, d), 0.5, dt),
        "w_r": (jax.random.normal(ks[0], (d, d)) * std).astype(dt),
        "w_k": (jax.random.normal(ks[1], (d, d)) * std).astype(dt),
        "w_v": (jax.random.normal(ks[2], (d, d)) * std).astype(dt),
        "w_g": (jax.random.normal(ks[3], (d, d)) * std).astype(dt),
        "w_o": (jax.random.normal(ks[4], (d, d)) * std).astype(dt),
        # data-dependent decay LoRA:  dd = tanh(x W1) W2 + bias
        "decay_w1": (jax.random.normal(ks[5], (d, DECAY_LORA)) * std).astype(dt),
        "decay_w2": (jax.random.normal(ks[6], (DECAY_LORA, d)) * 0.01).astype(dt),
        "decay_bias": jnp.full((d,), -6.0, jnp.float32),  # slow default decay
        "bonus_u": (jax.random.normal(ks[7], (h, dh)) * 0.1).astype(jnp.float32),
        "ln_x": jnp.zeros((d,), dt),  # group-norm scale on the head outputs
        # channel mix
        "cm_k": (jax.random.normal(ks[8], (d, cfg.d_ff)) * std).astype(dt),
        "cm_v": (jax.random.normal(ks[9], (cfg.d_ff, d)) * cfg.d_ff ** -0.5).astype(dt),
    }
    specs = {
        "mu": P(None, None), "mu_cm": P(None, None),
        "w_r": P(None, "model"), "w_k": P(None, "model"),
        "w_v": P(None, "model"), "w_g": P(None, "model"),
        "w_o": P("model", None),
        "decay_w1": P(None, None), "decay_w2": P(None, "model"),
        "decay_bias": P("model"), "bonus_u": P("model", None),
        "ln_x": P(None),
        "cm_k": P(None, "model"), "cm_v": P("model", None),
    }
    return params, specs


def init_rwkv_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> RWKVState:
    h, dh = head_layout(cfg)
    return RWKVState(
        s=jnp.zeros((batch, h, dh, dh), jnp.float32),
        x_prev_tm=jnp.zeros((batch, cfg.d_model), dtype),
        x_prev_cm=jnp.zeros((batch, cfg.d_model), dtype),
    )


def _projections(cfg: ArchConfig, params, x: jax.Array, x_shift: jax.Array):
    """r, k, v, g, decay(w) streams for time-mix.  x: (..., d).

    The five token-shift lerps share the identity  lerp_i @ W_i =
    x @ W_i + ((x_shift - x) * mu_i) @ W_i, so the r/k/v/g streams are two
    wide (d -> 4d) matmuls instead of four narrow ones over four distinct
    (B,S,d) lerp intermediates -- §Perf rwkv iteration 3 (fewer residency
    buffers, MXU-friendlier shapes).
    """
    mu = params["mu"].astype(x.dtype)
    delta = x_shift - x
    w_all = jnp.concatenate(
        [params["w_r"], params["w_k"], params["w_v"], params["w_g"]], axis=-1)
    d = x.shape[-1]
    base = x @ w_all                                     # (..., 4d)
    # per-stream mu folds into the delta operand, stream-blocked
    mu_block = jnp.concatenate(
        [jnp.broadcast_to(mu[i][..., None], (d, 1)) * w
         for i, w in ((0, params["w_r"]), (1, params["w_k"]),
                      (2, params["w_v"]), (4, params["w_g"]))], axis=-1)
    shift = delta @ mu_block                             # (..., 4d)
    rkvg = base + shift
    r, k, v, g = jnp.split(rkvg, 4, axis=-1)
    g = jax.nn.silu(g)
    lerp_w = x + mu[3] * delta
    dd = jnp.tanh(lerp_w @ params["decay_w1"]) @ params["decay_w2"]
    logw = -jnp.exp(jnp.clip(dd.astype(jnp.float32)
                             + params["decay_bias"], -20.0, 8.0))
    return r, k, v, g, logw  # decay w = exp(logw) in (0, 1), per channel


def _heads(x: jax.Array, h: int, dh: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (h, dh))


def _time_mix_sequential(rf, kf, vf, logw, u, s0):
    """Per-token recurrence (reference / paper-faithful baseline).

    rf/kf/vf/logw: (B, S, H, Dh) float32; u: (H, Dh); s0: (B, H, Dh, Dh).
    Returns (out (B,S,H,Dh) f32, s_fin).
    """
    w = jnp.exp(logw)

    def step(s_carry, inp):
        rt, kt, vt, wt = inp                                      # (B,H,Dh)...
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        ot = jnp.einsum("bhk,bhkv->bhv", rt, s_carry + u[None, :, :, None] * kv)
        s_new = wt[..., None] * s_carry + kv
        return s_new, ot

    xs = (jnp.moveaxis(rf, 1, 0), jnp.moveaxis(kf, 1, 0),
          jnp.moveaxis(vf, 1, 0), jnp.moveaxis(w, 1, 0))
    s_fin, outs = jax.lax.scan(step, s0, xs)                      # (S,B,H,Dh)
    return jnp.moveaxis(outs, 0, 1), s_fin


def _time_mix_chunked(rf, kf, vf, logw, u, s0, *, chunk: int):
    """Chunked closed form of the same recurrence (beyond-paper perf path).

    Within a chunk of C tokens the recurrence unrolls to matmuls:

      o_t   = (r_t . A_{t-1}) S_in  +  sum_{s<t} (r_t k_s exp(c_{t-1}-c_s)) v_s
              + (r_t . u . k_t) v_t
      S_out = A_C . S_in + sum_s (k_s exp(c_C - c_s)) v_s

    with c_t = cumsum(log w) (<= 0, so every exp argument is bounded by 0
    after causal masking -- numerically safe).  State HBM traffic drops
    from O(S) reads/writes of (B,H,Dh,Dh) to O(S/C).
    """
    b, s, h, dh = rf.shape
    out_dtype = rf.dtype
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        rf, kf, vf = z(rf), z(kf), z(vf)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def to_chunks(t):  # (B, S, H, Dh) -> (n, B, C, H, Dh)
        return jnp.moveaxis(
            t.reshape(b, n_chunks, chunk, h, dh), 1, 0)

    rc, kc, vc, lwc = map(to_chunks, (rf, kf, vf, logw))

    tri_lower_strict = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)

    def chunk_step(s_in, inp):
        r, k, v, lw = inp                                         # (B,C,H,Dh)
        # per-chunk f32 math over small slices; streams stay in the model
        # dtype between chunks (HBM traffic, iteration 2 of §Perf rwkv)
        r = r.astype(jnp.float32)
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        cum = jnp.cumsum(lw.astype(jnp.float32), axis=1)          # c_t (incl.)
        cum_prev = cum - lw                                       # c_{t-1}
        a_prev = jnp.exp(cum_prev)
        # inter-chunk: (r_t . A_{t-1}) S_in
        o_inter = jnp.einsum("bthk,bhkv->bthv", r * a_prev, s_in)
        # intra-chunk: pairwise decay exp(c_{t-1} - c_s), s < t
        diff = cum_prev[:, :, None] - cum[:, None, :]             # (B,t,s,H,Dh)
        dmat = jnp.exp(jnp.minimum(diff, 0.0))
        p = jnp.einsum("bthk,bshk,btshk->bths", r, k, dmat)
        p = p * tri_lower_strict[None, :, None, :]
        o_intra = jnp.einsum("bths,bshv->bthv", p, v)
        # current-token bonus
        o_diag = jnp.einsum("bthk,hk,bthk->bth", r, u, k)[..., None] * v
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)              # c_C - c_s
        a_end = jnp.exp(cum[:, -1])                               # (B,H,Dh)
        s_out = a_end[..., None] * s_in + jnp.einsum(
            "bshk,bshv->bhkv", k * decay_to_end, v)
        return s_out, (o_inter + o_intra + o_diag).astype(out_dtype)

    s_fin, outs = jax.lax.scan(chunk_step, s0, (rc, kc, vc, lwc))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, n_chunks * chunk, h, dh)[:, :s]
    return out, s_fin


def time_mix_chunk(cfg: ArchConfig, params, x: jax.Array, state: RWKVState,
                   *, chunk: int = 0):
    """Time-mix over a full sequence.  x: (B, S, d) -> (out, new_state).

    ``chunk`` (or cfg.scan_chunk) > 0 selects the chunked closed form;
    0 runs the per-token reference recurrence.
    """
    b, s, d = x.shape
    h, dh = head_layout(cfg)
    chunk = chunk or cfg.scan_chunk
    # token shift: previous token (state carries the boundary)
    x_shift = jnp.concatenate([state.x_prev_tm[:, None], x[:, :-1]], axis=1)
    r, k, v, g, logw_full = _projections(cfg, params, x, x_shift)
    r, k, v = _heads(r, h, dh), _heads(k, h, dh), _heads(v, h, dh)
    u = params["bonus_u"]                                         # (H, Dh)

    logw = _heads(logw_full, h, dh)

    # f32 streams measured *cheaper* than bf16 streams here (bf16 splits
    # the chunk fusions with converts; §Perf rwkv iteration 2, refuted)
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if chunk and s > 1:
        outs, s_fin = _time_mix_chunked(rf, kf, vf, logw, u, state.s,
                                        chunk=chunk)
    else:
        outs, s_fin = _time_mix_sequential(rf, kf, vf, logw, u, state.s)
    out = outs.reshape(b, s, d).astype(x.dtype)

    # per-head group norm then gate
    out = out.reshape(b, s, h, dh)
    mean = jnp.mean(out.astype(jnp.float32), axis=-1, keepdims=True)
    var = jnp.var(out.astype(jnp.float32), axis=-1, keepdims=True)
    out = ((out - mean) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)
    out = out.reshape(b, s, d) * (1.0 + params["ln_x"])
    out = (out * g) @ params["w_o"]
    out = sharding.constraint(out, P(sharding.batch_axes(), None, None))
    new_state = RWKVState(s=s_fin, x_prev_tm=x[:, -1], x_prev_cm=state.x_prev_cm)
    return out, new_state


def channel_mix(cfg: ArchConfig, params, x: jax.Array, state: RWKVState):
    """RWKV channel-mix (squared-ReLU FFN with token shift)."""
    x_shift = jnp.concatenate([state.x_prev_cm[:, None], x[:, :-1]], axis=1)
    mu = params["mu_cm"][0].astype(x.dtype)
    xk = x + mu * (x_shift - x)
    hidden = jnp.square(jax.nn.relu(xk @ params["cm_k"]))
    hidden = sharding.constraint(hidden, P(sharding.batch_axes(), None, "model"))
    out = hidden @ params["cm_v"]
    out = sharding.constraint(out, P(sharding.batch_axes(), None, None))
    return out, RWKVState(s=state.s, x_prev_tm=state.x_prev_tm, x_prev_cm=x[:, -1])


def decode_step(cfg: ArchConfig, params, x: jax.Array, state: RWKVState):
    """One-token time-mix + channel-mix.  x: (B, 1, d)."""
    b = x.shape[0]
    h, dh = head_layout(cfg)
    xt = x[:, 0]
    r, k, v, g, logw = _projections(cfg, params, xt, state.x_prev_tm)
    w = jnp.exp(logw)
    r, k, v, w = (_heads(t, h, dh) for t in (r, k, v, w))
    u = params["bonus_u"]
    kv = jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32))
    o = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32),
                   state.s + u[None, :, :, None] * kv)
    s_new = w.astype(jnp.float32)[..., None] * state.s + kv

    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = ((o - mean) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)
    o = o.reshape(b, cfg.d_model) * (1.0 + params["ln_x"])
    tm_out = (o * g) @ params["w_o"]

    return tm_out[:, None], RWKVState(s=s_new, x_prev_tm=xt, x_prev_cm=state.x_prev_cm)


def decode_channel_mix(cfg: ArchConfig, params, x: jax.Array, state: RWKVState):
    xt = x[:, 0]
    mu = params["mu_cm"][0].astype(x.dtype)
    xk = xt + mu * (state.x_prev_cm - xt)
    out = jnp.square(jax.nn.relu(xk @ params["cm_k"])) @ params["cm_v"]
    return out[:, None], RWKVState(s=state.s, x_prev_tm=state.x_prev_tm, x_prev_cm=xt)
