"""BiCompFL-GR-CFL: the paper's technique in *conventional* FL.

    PYTHONPATH=src python examples/cfl_gradient_compression.py

Clients compute weight deltas, quantize them with stochastic SignSGD
(Q_s of paper Sec. 4), and convey samples through MRC against the
uninformative Ber(1/2) prior; the federator relays indices on the downlink
(global shared randomness).  Compared side by side with DoubleSqueeze and
dense FedAvg at equal round counts.
"""
import time

import jax

from repro.fl.baselines import BaselineConfig, run_baseline
from repro.fl.data import make_synthetic, partition_iid
from repro.fl.federator import CFLConfig, run_bicompfl_cfl
from repro.fl.nets import make_mlp
from repro.fl.tasks import make_cfl_task


def main():
    key = jax.random.PRNGKey(0)
    train, test = make_synthetic(key, n_train=2000, n_test=500, hw=10, noise=0.4)
    shards = partition_iid(jax.random.fold_in(key, 1), train, 10, 200)
    net = make_mlp(in_dim=100, widths=(256,))
    task, theta0 = make_cfl_task(net, jax.random.fold_in(key, 2),
                                 test.x, test.y, local_epochs=5,
                                 batch_size=32, local_lr=3e-3)

    rounds = 12
    t0 = time.time()
    out = run_bicompfl_cfl(task, theta0, shards,
                           CFLConfig(rounds=rounds, server_lr=1.0))
    print(f"BiCompFL-GR-CFL : acc {out['max_acc']:.3f}  "
          f"bpp {out['meter']['bpp']:.3f}  [{time.time()-t0:.0f}s]")

    for scheme in ("doublesqueeze", "fedavg"):
        t0 = time.time()
        res = run_baseline(task, theta0, shards,
                           BaselineConfig(scheme=scheme, rounds=rounds,
                                          server_lr=1.0))
        print(f"{scheme:15s} : acc {res['max_acc']:.3f}  "
              f"bpp {res['meter']['bpp']:.3f}  [{time.time()-t0:.0f}s]")


if __name__ == "__main__":
    main()
