"""Pallas TPU kernel: chunked RWKV-6 time-mix (decay-weighted linear attn).

EXPERIMENTS.md §Perf pair 1 ends with the XLA chunked closed form 25x off
the compute roofline because the pairwise-decay tensor and the chunk
streams still round-trip HBM.  This kernel is the TPU-native step: one
grid cell processes one (batch*head, chunk) tile with the (Dh, Dh) state
carried in VMEM f32 scratch across the (sequential) chunk axis -- state,
scores and decay tiles never reach HBM.

Math per chunk (c = cumsum(log w), all <= 0):

    o_t   = (r_t . e^{c_{t-1}}) S  +  sum_{s<t} (r_t k_s e^{c_{t-1}-c_s}) v_s
            + (r_t . u . k_t) v_t
    S'    = e^{c_C} . S + sum_s (k_s e^{c_C - c_s}) v_s^T

Exactly the math of ``models.rwkv6._time_mix_chunked`` (tested against it
and the per-token reference).  Grid: (BH, S/C) with the chunk axis
innermost/sequential; tiles (C, Dh) with C = Dh = 64 (one VREG-friendly
square; VMEM per step ~ 4 * 64*64*4 + dmat 64*64*64*4 ~ 1.1 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 64


def _rwkv_chunk_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *,
                       n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)       # (C, Dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)     # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)       # (1, Dh) bonus row

    cum = jnp.cumsum(lw, axis=0)           # c_t inclusive
    cum_prev = cum - lw                    # c_{t-1}

    s_in = s_scr[...]
    o_inter = jnp.dot(r * jnp.exp(cum_prev), s_in,
                      preferred_element_type=jnp.float32)      # (C, Dh)

    # pairwise decay exp(c_{t-1} - c_s) for s < t, per channel
    diff = cum_prev[:, None, :] - cum[None, :, :]              # (C, C, Dh)
    dmat = jnp.exp(jnp.minimum(diff, 0.0))
    p = jnp.einsum("tk,sk,tsk->ts", r, k, dmat)                # (C, C)
    c = r.shape[0]
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    p = jnp.where(si < ti, p, 0.0)
    o_intra = jnp.dot(p, v, preferred_element_type=jnp.float32)

    o_diag = jnp.sum(r * u * k, axis=1, keepdims=True) * v

    o_ref[0] = (o_inter + o_intra + o_diag).astype(o_ref.dtype)

    decay_to_end = jnp.exp(cum[-1:] - cum)                     # (C, Dh)
    s_scr[...] = jnp.exp(cum[-1])[:, None] * s_in + jnp.dot(
        (k * decay_to_end).T, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv_chunk_pallas(r: jax.Array, k: jax.Array, v: jax.Array,
                      logw: jax.Array, u: jax.Array, *,
                      interpret: bool = True) -> jax.Array:
    """r/k/v/logw: (BH, S, Dh) with S % CHUNK == 0; u: (BH, 1, Dh).

    Returns the time-mix output (BH, S, Dh); zero initial state.  Use
    ``ops.rwkv_time_mix`` for the general-shape entry point.
    """
    bh, s, dh = r.shape
    if s % CHUNK != 0:
        raise ValueError(
            f"rwkv_chunk_pallas needs S % {CHUNK} == 0, got S={s} "
            "(use ops.rwkv_time_mix for the padded general-shape entry point)")
    n_chunks = s // CHUNK
    grid = (bh, n_chunks)
    kernel = functools.partial(_rwkv_chunk_kernel, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, CHUNK, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, CHUNK, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, CHUNK, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, CHUNK, dh), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), r.dtype),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
