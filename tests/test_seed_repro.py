"""Seed reproducibility: one ``seed`` pins the whole run.

Guards the cohort-schedule machinery through the numpy->jax RNG migration:
the engine now precomputes the participation schedule up front (numpy mode
replays the seed's ``default_rng(seed+17)`` draws; jax mode derives cohorts
from the round key), and either way two runs of the same spec with the same
seed must produce identical schedules, histories, and models -- while a
different seed must actually change the cohorts.
"""
import jax
import numpy as np
import pytest

from repro.core.blocks import FixedAllocation
from repro.fl import registry
from repro.fl.engine import FLEngine
from repro.fl.data import make_synthetic, partition_iid
from repro.fl.nets import make_mlp
from repro.fl.tasks import make_mask_task


@pytest.fixture(scope="module")
def setup():
    k = jax.random.PRNGKey(7)
    train, test = make_synthetic(k, n_train=240, n_test=120, hw=6, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, 4, 60)
    net = make_mlp(in_dim=36, widths=(24,), signed_constant=True)
    task = make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                          local_epochs=1, batch_size=30)
    return task, shards


def _spec():
    return registry.bicompfl_spec("PR", allocation=FixedAllocation(64),
                                  n_is=16, n_dl=4, participation=0.5)


@pytest.mark.parametrize("cohort_rng", ["numpy", "jax"])
def test_same_seed_same_run(setup, cohort_rng):
    task, shards = setup
    outs = [FLEngine(task, _spec()).run(shards, rounds=3, seed=23,
                                        cohort_rng=cohort_rng)
            for _ in range(2)]
    a, b = outs
    np.testing.assert_array_equal(a["active_schedule"], b["active_schedule"])
    assert a["history"] == b["history"]
    np.testing.assert_array_equal(np.asarray(a["theta"]),
                                  np.asarray(b["theta"]))
    np.testing.assert_array_equal(np.asarray(a["theta_hat"]),
                                  np.asarray(b["theta_hat"]))


@pytest.mark.parametrize("cohort_rng", ["numpy", "jax"])
def test_different_seed_different_cohorts(setup, cohort_rng):
    """3 rounds x choose(4,2) cohorts: seeds colliding on the whole schedule
    would indicate the seed is not actually threaded through."""
    task, shards = setup
    scheds = [FLEngine(task, _spec()).run(shards, rounds=3, seed=s,
                                          cohort_rng=cohort_rng)
              ["active_schedule"] for s in (23, 24)]
    assert not np.array_equal(scheds[0], scheds[1])


def test_cohort_schedule_shapes_and_determinism():
    for rng in ("numpy", "jax"):
        s1 = FLEngine.cohort_schedule(5, 10, 4, 3, rng)
        s2 = FLEngine.cohort_schedule(5, 10, 4, 3, rng)
        np.testing.assert_array_equal(s1, s2)
        assert s1.shape == (5, 4)
        assert (np.sort(s1, axis=1) == s1).all()          # sorted cohorts
        assert (s1 >= 0).all() and (s1 < 10).all()
        for row in s1:                                    # no replacement
            assert len(set(row.tolist())) == 4
    full = FLEngine.cohort_schedule(3, 4, 4, 0)
    np.testing.assert_array_equal(full, np.tile(np.arange(4), (3, 1)))
