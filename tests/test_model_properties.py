"""Model-level invariants: causality, sliding-window locality, decode
position-independence of the prefix."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as T

KEY = jax.random.PRNGKey(41)


def _logits(cfg, params, toks, **kw):
    model = T.build(cfg)
    out, _ = T.forward(model, params, {"tokens": toks}, kv_chunk=8, **kw)
    return np.asarray(out, np.float32)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "rwkv6-1.6b", "jamba-v0.1-52b",
                                  "kimi-k2-1t-a32b"])
def test_causality(arch):
    """Perturbing a future token must not change past logits.

    MoE caveat: with finite expert capacity, a later token can evict an
    earlier token of a *different* sequence from an expert queue (capacity
    contention is batch-global in GShard-style dispatch) -- so strict
    causality only holds in the no-drop limit; we raise the capacity
    factor to guarantee it here.
    """
    cfg = C.get(arch).reduced()
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = T.build(cfg)
    params, _ = T.init_params(model, KEY)
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 12), 0, cfg.vocab)
    l1 = _logits(cfg, params, toks)
    toks2 = toks.at[:, 8].set((toks[:, 8] + 7) % cfg.vocab)
    l2 = _logits(cfg, params, toks2)
    np.testing.assert_allclose(l1[:, :8], l2[:, :8], rtol=1e-4, atol=1e-4)
    assert np.abs(l1[:, 8:] - l2[:, 8:]).max() > 1e-6  # future does change


def test_encoder_is_not_causal():
    cfg = C.get("hubert-xlarge").reduced()
    model = T.build(cfg)
    params, _ = T.init_params(model, KEY)
    x = 0.02 * jax.random.normal(KEY, (1, 10, cfg.d_model))
    l1, _ = T.forward(model, params, {"inputs": x}, kv_chunk=8)
    x2 = x.at[:, 9].add(1.0)
    l2, _ = T.forward(model, params, {"inputs": x2}, kv_chunk=8)
    # bidirectional: changing the last frame changes the first frame's logits
    assert float(jnp.abs(l1[:, 0] - l2[:, 0]).astype(jnp.float32).max()) > 1e-6


def test_sliding_window_locality():
    """With window w, tokens further than w back must not influence logits."""
    cfg = dataclasses.replace(C.get("qwen3-1.7b").reduced(), sliding_window=4)
    model = T.build(cfg)
    params, _ = T.init_params(model, KEY)
    toks = jax.random.randint(jax.random.fold_in(KEY, 2), (1, 16), 0, cfg.vocab)
    l1 = _logits(cfg, params, toks)
    # perturb token 0; logits at positions >= n_layers*window away are
    # unaffected (receptive field grows by w per layer)
    toks2 = toks.at[:, 0].set((toks[:, 0] + 3) % cfg.vocab)
    l2 = _logits(cfg, params, toks2)
    reach = cfg.n_layers * cfg.sliding_window
    if reach < 16:
        np.testing.assert_allclose(l1[:, reach:], l2[:, reach:],
                                   rtol=1e-4, atol=1e-4)
    # and positions inside one window do change
    assert np.abs(l1[:, 1:4] - l2[:, 1:4]).max() > 1e-6


def test_vlm_image_tokens_attend():
    """Image embeddings occupy the first slots and influence later logits."""
    cfg = C.get("qwen2-vl-72b").reduced()
    model = T.build(cfg)
    params, _ = T.init_params(model, KEY)
    b, s = 1, 24
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (b, s), 0, cfg.vocab)
    pos = jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, 3)).astype(jnp.int32)
    img1 = 0.02 * jax.random.normal(KEY, (b, cfg.vlm_image_tokens, cfg.d_model))
    batch = {"tokens": toks, "image_embeds": img1, "positions": pos}
    l1, _ = T.forward(model, params, batch, kv_chunk=8)
    batch2 = dict(batch, image_embeds=img1 + 0.1)
    l2, _ = T.forward(model, params, batch2, kv_chunk=8)
    assert float(jnp.abs(l1[:, -1] - l2[:, -1]).astype(jnp.float32).max()) > 1e-6
