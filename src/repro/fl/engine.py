"""The one FL round loop: local-train -> uplink -> aggregate -> downlink.

Every training loop in the repo -- the four BiCompFL variants, BiCompFL-CFL,
and all seven non-stochastic baselines -- is an :class:`EngineSpec`
(uplink channel, downlink channel, aggregator, plus block allocation and
participation policy) executed by :class:`FLEngine`.  The engine owns the
things every scheme shares and that used to be copy-pasted per loop:

* shared-randomness key schedule (round key, per-client training keys),
* partial participation (cohort sampling; inactive clients are *not*
  trained -- the seed loops wastefully vmapped ``local_train`` over the full
  cohort even when ``participation < 1``),
* the host-side block-allocation control plane,
* periodic error-feedback synchronisation (CSER / LIEC style ``flush``),
* BitMeter accounting and evaluation history.

The engine reproduces the seed loops bit-for-bit at full participation
(tests/test_engine_parity.py); see DESIGN.md for the API contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrc
from repro.core.bernoulli import bern_kl, clip01
from repro.core.bitmeter import BitMeter
from .channels import BlockPlan, RoundContext, ServerUpdate, TAG_TRAIN
from .data import Dataset


# ---------------------------------------------------------------------------
# Aggregators: uplink output -> proposed server update.
# ---------------------------------------------------------------------------


class MeanModelAggregator:
    """BiCompFL: the mean of the conveyed posterior samples *is* the model."""

    def __call__(self, ctx, theta, up_out) -> ServerUpdate:
        return ServerUpdate(theta=jnp.mean(up_out, axis=0))


@dataclass
class MeanDeltaAggregator:
    """Conventional FL: average the (compressed) deltas, step the server."""

    server_lr: float = 1.0

    def __call__(self, ctx, theta, up_out) -> ServerUpdate:
        g = jnp.mean(up_out, axis=0)
        return ServerUpdate(theta=theta - self.server_lr * g, delta=g,
                            lr=self.server_lr)


# ---------------------------------------------------------------------------
# Engine.
# ---------------------------------------------------------------------------


@dataclass
class EngineSpec:
    """A complete FL scheme: who compresses what, in which direction."""

    uplink: Any
    downlink: Any
    aggregator: Any
    allocation: Any = None       # block-allocation strategy (MRC schemes)
    participation: float = 1.0   # fraction of clients active per round
    sync_period: int = 0         # 0 = never; else flush EF memories every k
    name: str = ""


class FLEngine:
    """Runs an :class:`EngineSpec` against a task and sharded dataset."""

    def __init__(self, task, spec: EngineSpec):
        self.task = task
        self.spec = spec

    def run(self, shards: Dataset, theta0: Optional[jax.Array] = None, *,
            rounds: int, seed: int = 0, eval_every: int = 1) -> Dict[str, Any]:
        task, spec = self.task, self.spec
        # Stateful channels (error-feedback memories) must start fresh: a
        # spec may be run more than once.
        for chan in (spec.uplink, spec.downlink):
            reset = getattr(chan, "reset", None)
            if reset is not None:
                reset()
        n = int(shards.x.shape[0])
        theta = task.init_theta() if theta0 is None else theta0
        d = int(theta.shape[0])
        theta_hat = jnp.tile(theta[None], (n, 1))
        meter = BitMeter(
            n_clients=n, d=d,
            broadcast_downlink_shareable=getattr(
                spec.downlink, "broadcast_shareable", True))
        base = jax.random.PRNGKey(seed)
        n_active = max(1, int(round(spec.participation * n)))
        rng = np.random.default_rng(seed + 17)
        history: List[Dict[str, float]] = []

        for t in range(rounds):
            kt = mrc.round_key(base, t)
            active = np.sort(rng.choice(n, size=n_active, replace=False)) \
                if n_active < n else np.arange(n)

            # ---- local training: only the active cohort ------------------
            train_keys = jax.random.split(jax.random.fold_in(kt, TAG_TRAIN), n)
            if n_active < n:
                priors = theta_hat[active]
                xs, ys, keys = (shards.x[active], shards.y[active],
                                train_keys[active])
            else:  # full participation: no device-side gather/copy needed
                priors, xs, ys, keys = theta_hat, shards.x, shards.y, train_keys
            payload = jax.vmap(task.local_train)(priors, xs, ys, keys)

            # ---- block allocation (host-side control plane) --------------
            plan = None
            if spec.allocation is not None:
                kl = None
                if getattr(spec.allocation, "needs_kl", True):
                    kl = np.asarray(jnp.mean(jax.vmap(bern_kl)(
                        payload, clip01(priors)), axis=0))
                size, n_blocks, seg_ids, overhead = spec.allocation.plan(kl, d)
                plan = BlockPlan(size=size, n_blocks=n_blocks,
                                 seg_ids=seg_ids, overhead_bits=overhead)

            ctx = RoundContext(t=t, key=kt, n_clients=n, d=d, active=active,
                               plan=plan)

            # ---- uplink -> aggregate -> downlink -------------------------
            up_out, ul_bits = spec.uplink.transmit(ctx, payload, priors)
            update = spec.aggregator(ctx, theta, up_out)
            theta, theta_hat, dl_bits = spec.downlink.distribute(
                ctx, update, theta, theta_hat)

            # ---- periodic EF synchronisation (CSER / LIEC) ---------------
            if spec.sync_period and (t + 1) % spec.sync_period == 0:
                r_up, b_up = spec.uplink.flush(n, d)
                r_dn, b_dn = spec.downlink.flush(n, d)
                # flush at the aggregator's step size (update.lr), so a
                # hand-built spec cannot desync the reset from the rounds
                theta = theta - update.lr * (r_up + r_dn)
                theta_hat = jnp.tile(theta[None], (n, 1))
                ul_bits += b_up
                dl_bits += b_dn

            overhead_bits = plan.overhead_bits * n if plan is not None else 0.0
            meter.add_round(ul_bits, dl_bits, overhead_bits=overhead_bits)

            if (t + 1) % eval_every == 0 or t == rounds - 1:
                acc = task.evaluate(theta)
                history.append({"round": t + 1, "acc": float(acc),
                                "cum_bits": meter.total_bits,
                                "bpp_so_far": meter.total_bpp})

        return {"history": history, "meter": meter.summary(),
                "theta": theta, "theta_hat": theta_hat,
                "final_acc": history[-1]["acc"] if history else float("nan"),
                "max_acc": max(h["acc"] for h in history) if history else float("nan")}


def run_spec(task, spec: EngineSpec, shards: Dataset,
             theta0: Optional[jax.Array] = None, *, rounds: int,
             seed: int = 0, eval_every: int = 1) -> Dict[str, Any]:
    """Convenience one-shot: build an engine and run it."""
    return FLEngine(task, spec).run(shards, theta0, rounds=rounds, seed=seed,
                                    eval_every=eval_every)
