"""Block-allocation strategies for MRC (paper Section 3 / Appendix E).

* ``FixedAllocation``       -- constant block size d/B across rounds.
* ``AdaptiveAvgAllocation`` -- the paper's low-complexity proposal: keep equal
  block sizes but re-optimize the (single) size each round so that the
  *average* KL per block tracks the target log(n_is); only one size needs to
  be transmitted (log2(b_max) bits when it changes).
* ``AdaptiveAllocation``    -- Isik et al. (2024): variable block boundaries
  with (approximately) equal KL mass per block; boundaries are transmitted.

To keep JIT shapes static, adaptive sizes are quantized to powers of two in
[min_block, max_block]; AdaptiveAllocation represents boundaries through a
segment-id vector with a static maximum number of segments.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import numpy as np

from .bernoulli import bern_kl


def _pad_to(d: int, block: int) -> int:
    return -(-d // block) * block


@dataclass
class FixedAllocation:
    block_size: int = 256

    name = "Fixed"
    needs_kl = False  # plan() ignores the KL profile; lets the engine skip it
    static_plan = True  # round-independent: eligible for the fused scan path

    def blocks_for(self, d: int) -> int:
        return _pad_to(d, self.block_size) // self.block_size

    def plan(self, kl_per_param: Optional[np.ndarray], d: int):
        """Return (block_size, n_blocks, seg_ids=None, overhead_bits)."""
        return self.block_size, self.blocks_for(d), None, 0.0


@dataclass
class AdaptiveAvgAllocation:
    """Equal-size blocks, size re-tuned each round from the average KL.

    Target: per-block KL (in nats) ~ target_ratio * log(n_is); block sizes
    are powers of two in [min_block, max_block]. The size update costs
    log2(log2(max_block)) ~ a few bits; we book ceil(log2(max_block)) bits.
    """

    n_is: int = 256
    target_ratio: float = 1.0
    min_block: int = 32
    max_block: int = 4096

    name = "Adaptive-Avg"
    needs_kl = True
    static_plan = False  # per-round size retuning is host control plane

    def plan(self, kl_per_param: Optional[np.ndarray], d: int):
        if kl_per_param is None:
            size = self.min_block * 8
        else:
            mean_kl = float(np.mean(kl_per_param)) + 1e-12
            target = self.target_ratio * math.log(self.n_is)
            size = target / mean_kl
        size = 2 ** int(np.clip(np.round(np.log2(max(size, 1))),
                                math.log2(self.min_block), math.log2(self.max_block)))
        n_blocks = _pad_to(d, size) // size
        return size, n_blocks, None, math.ceil(math.log2(self.max_block))


@dataclass
class AdaptiveAllocation:
    """Variable boundaries with equal KL mass per block (Isik et al. 2024).

    Number of blocks B is chosen so that total KL / B ~ log(n_is); boundaries
    are found by cumulative-KL binning. Overhead: B * ceil(log2(max_block))
    bits to transmit the block intervals (paper, Appendix E).
    """

    n_is: int = 256
    target_ratio: float = 1.0
    min_blocks: int = 4
    max_block: int = 4096

    name = "Adaptive"
    needs_kl = True
    static_plan = False  # per-round KL binning is host control plane

    def plan(self, kl_per_param: Optional[np.ndarray], d: int):
        if kl_per_param is None:
            # Cold start: fall back to fixed 256-size blocks.
            size = 256
            n_blocks = _pad_to(d, size) // size
            seg = np.minimum(np.arange(d) // size, n_blocks - 1)
            return None, n_blocks, seg.astype(np.int32), 0.0
        total = float(np.sum(kl_per_param)) + 1e-12
        target = self.target_ratio * math.log(self.n_is)
        n_blocks = max(self.min_blocks, int(math.ceil(total / target)))
        n_blocks = min(n_blocks, max(self.min_blocks, d // 8))
        cum = np.cumsum(np.asarray(kl_per_param, dtype=np.float64))
        # boundary so each block holds ~ total/n_blocks KL mass
        edges = np.searchsorted(cum, np.linspace(0, total, n_blocks + 1)[1:-1])
        seg = np.zeros(d, dtype=np.int32)
        seg[edges] += 1
        seg = np.cumsum(seg).astype(np.int32)
        overhead = n_blocks * math.ceil(math.log2(self.max_block))
        return None, int(seg.max()) + 1, seg, float(overhead)


def kl_per_param(q, p) -> np.ndarray:
    return np.asarray(bern_kl(q, p))
