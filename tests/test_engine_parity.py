"""Fixed-seed parity: the composable Channel/Engine API reproduces the
seed's monolithic loops bit-for-bit.

Each case runs the vendored legacy loop (tests/legacy_seed_impl.py) and the
new engine-backed wrapper on the same tiny synthetic task and asserts equal
histories (accuracy floats, cumulative bits), meters, and final model /
client-estimate arrays -- exact equality, no tolerances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocks import AdaptiveAllocation, FixedAllocation
from repro.fl.baselines import BaselineConfig, run_baseline
from repro.fl.data import make_synthetic, partition_iid
from repro.fl.federator import (BiCompFLConfig, CFLConfig, run_bicompfl,
                                run_bicompfl_cfl)
from repro.fl.nets import make_mlp
from repro.fl.tasks import make_cfl_task, make_mask_task

from legacy_seed_impl import (run_baseline_legacy, run_bicompfl_cfl_legacy,
                              run_bicompfl_legacy)


@pytest.fixture(scope="module")
def mask_setup():
    k = jax.random.PRNGKey(3)
    train, test = make_synthetic(k, n_train=240, n_test=120, hw=6, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, 3, 80)
    net = make_mlp(in_dim=36, widths=(32,), signed_constant=True)
    task = make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                          local_epochs=1, batch_size=40)
    return task, shards


@pytest.fixture(scope="module")
def cfl_setup():
    k = jax.random.PRNGKey(4)
    train, test = make_synthetic(k, n_train=240, n_test=120, hw=6, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, 3, 80)
    net = make_mlp(in_dim=36, widths=(32,))
    task, theta0 = make_cfl_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                                 local_epochs=2, batch_size=40, local_lr=3e-3)
    return task, theta0, shards


def _assert_same(old, new, *, check_theta_hat=True):
    assert len(old["history"]) == len(new["history"])
    for ho, hn in zip(old["history"], new["history"]):
        for key in ho:
            assert hn[key] == ho[key], (key, ho, hn)
    for key in old["meter"]:
        assert new["meter"][key] == old["meter"][key], key
    np.testing.assert_array_equal(np.asarray(old["theta"]),
                                  np.asarray(new["theta"]))
    if check_theta_hat and "theta_hat" in old:
        np.testing.assert_array_equal(np.asarray(old["theta_hat"]),
                                      np.asarray(new["theta_hat"]))
    assert new["final_acc"] == old["final_acc"]
    assert new["max_acc"] == old["max_acc"]


@pytest.mark.parametrize("variant", ["GR", "GR-Reconst", "PR", "PR-SplitDL"])
def test_bicompfl_variant_parity(mask_setup, variant):
    task, shards = mask_setup
    cfg = BiCompFLConfig(variant=variant, rounds=2, n_is=16,
                         allocation=FixedAllocation(64), seed=11)
    _assert_same(run_bicompfl_legacy(task, shards, cfg),
                 run_bicompfl(task, shards, cfg))


def test_bicompfl_adaptive_parity(mask_setup):
    """Segment-codec path (AdaptiveAllocation) through the engine."""
    task, shards = mask_setup
    cfg = BiCompFLConfig(variant="GR", rounds=2, n_is=16,
                         allocation=AdaptiveAllocation(n_is=16), seed=11)
    _assert_same(run_bicompfl_legacy(task, shards, cfg),
                 run_bicompfl(task, shards, cfg))


def test_bicompfl_pr_partial_parity(mask_setup):
    """Partial participation: the engine skips training inactive clients but
    must reproduce the legacy loop (which trained everyone) exactly."""
    task, shards = mask_setup
    cfg = BiCompFLConfig(variant="PR", rounds=3, n_is=16, participation=0.67,
                         allocation=FixedAllocation(64), seed=13)
    _assert_same(run_bicompfl_legacy(task, shards, cfg),
                 run_bicompfl(task, shards, cfg))


@pytest.mark.parametrize("scheme", ["fedavg", "memsgd", "doublesqueeze",
                                    "neolithic", "cser", "liec", "m3"])
def test_baseline_parity(cfl_setup, scheme):
    task, theta0, shards = cfl_setup
    # reset_period=2 exercises the CSER/LIEC flush path inside 3 rounds
    cfg = BaselineConfig(scheme=scheme, rounds=3, server_lr=1.0, seed=5,
                         reset_period=2)
    _assert_same(run_baseline_legacy(task, theta0, shards, cfg),
                 run_baseline(task, theta0, shards, cfg))


def test_cfl_parity(cfl_setup):
    task, theta0, shards = cfl_setup
    cfg = CFLConfig(rounds=2, n_is=16, block_size=16, server_lr=1.0, seed=7)
    _assert_same(run_bicompfl_cfl_legacy(task, theta0, shards, cfg),
                 run_bicompfl_cfl(task, theta0, shards, cfg),
                 check_theta_hat=False)
