"""Per-architecture smoke tests: reduced config (2 layers, d_model<=512,
<=4 experts), one forward + one train step on CPU, asserting output shapes
and the absence of NaNs; one decode step where the family supports it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro import optim
from repro.launch import train as train_lib
from repro.models import transformer as T

ARCHS = list(C.ALIASES)


def _smoke_batch(cfg, b=2, s=16, key=jax.random.PRNGKey(3)):
    if cfg.embed_inputs:
        batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
                 "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
        if cfg.vlm_image_tokens:
            batch["image_embeds"] = 0.02 * jax.random.normal(
                key, (b, cfg.vlm_image_tokens, cfg.d_model))
            if cfg.rope_kind == "mrope":
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(s)[None, :, None], (b, s, 3)).astype(jnp.int32)
    else:
        batch = {"inputs": 0.02 * jax.random.normal(key, (b, s, cfg.d_model)),
                 "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_constraints(arch):
    cfg = C.get(arch).reduced()
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    assert cfg.vocab <= 512


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = C.get(arch).reduced()
    model = T.build(cfg)
    params, _ = T.init_params(model, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    logits, aux = T.forward(model, params, batch, kv_chunk=8)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss_no_nan(arch):
    cfg = C.get(arch).reduced()
    model = T.build(cfg)
    params, _ = T.init_params(model, jax.random.PRNGKey(0))
    opt = optim.adam(1e-2)
    step = jax.jit(train_lib.make_train_step(model, opt, microbatches=1,
                                             kv_chunk=8))
    opt_state = opt.init(params)
    batch = _smoke_batch(cfg)
    loss0, params, opt_state = step(params, opt_state, batch, jax.random.PRNGKey(1))
    loss1, params, opt_state = step(params, opt_state, batch, jax.random.PRNGKey(2))
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1))
    # one Adam step on the same batch must not increase the loss much
    assert float(loss1) < float(loss0) + 0.5, (float(loss0), float(loss1))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if C.get(a).supports_decode])
def test_decode_step_matches_shapes(arch):
    cfg = C.get(arch).reduced()
    model = T.build(cfg)
    params, _ = T.init_params(model, jax.random.PRNGKey(0))
    b, s_max = 2, 32
    cache = T.init_cache(model, b, s_max)
    toks = jnp.zeros((b, 1), jnp.int32)
    for pos in range(3):
        logits, cache = T.serve_step(model, params, cache, toks, jnp.int32(pos))
        assert logits.shape == (b, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        toks = jnp.argmax(logits.astype(jnp.float32), -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "rwkv6-1.6b", "jamba-v0.1-52b"])
def test_decode_consistent_with_forward(arch):
    """Greedy decode over a short prompt must produce the same next-token
    argmax as the teacher-forced forward pass (KV-cache correctness)."""
    cfg = C.get(arch).reduced()
    model = T.build(cfg)
    params, _ = T.init_params(model, jax.random.PRNGKey(0))
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab)
    logits_fwd, _ = T.forward(model, params, {"tokens": toks}, kv_chunk=8)

    cache = T.init_cache(model, b, 16)
    logits_dec = None
    for t in range(s):
        logits_dec, cache = T.serve_step(model, params, cache,
                                         toks[:, t:t + 1], jnp.int32(t))
    a_fwd = np.asarray(jnp.argmax(logits_fwd[:, -1].astype(jnp.float32), -1))
    a_dec = np.asarray(jnp.argmax(logits_dec[:, 0].astype(jnp.float32), -1))
    np.testing.assert_array_equal(a_fwd, a_dec)


@pytest.mark.parametrize("arch", ARCHS)
def test_skip_matrix_documented(arch):
    """The skip rules of the assignment are what shape_supported reports."""
    cfg = C.get(arch)
    if not cfg.supports_decode:
        assert C.shape_supported(cfg, "decode_32k")
        assert C.shape_supported(cfg, "long_500k")
    if cfg.arch_type == "dense" and not (cfg.sliding_window or cfg.long_context_window):
        assert C.shape_supported(cfg, "long_500k")
    assert C.shape_supported(cfg, "train_4k") is None
    assert C.shape_supported(cfg, "prefill_32k") is None
