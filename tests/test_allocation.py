"""Unit/property tests for the block-allocation control plane
(``repro.core.blocks``): host exact plans and the fused bucket API.

Pinned properties:

* ``plan()`` is deterministic -- a fixed KL profile always yields the
  identical plan (sizes, segment ids, overhead);
* bucket rounding is *monotone* -- more KL never selects a bucket with
  fewer blocks (bigger blocks);
* bucket rounding is *conservative* -- the bucketed plan never allocates
  more bits than the exact plan's budget plus the allocation's declared
  ``bucket_overhead_bits`` (zero for both: AdaptiveAvg's buckets are the
  exact pow2 plan space, AdaptiveAllocation floors onto its grid);
* the traced bucket selection agrees with the host ``plan()`` on the same
  profile (AdaptiveAvg: identical size; Adaptive: the largest grid point
  at or below the exact block count).
"""
import math

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

import jax.numpy as jnp
import numpy as np

from repro.core.blocks import (AdaptiveAllocation, AdaptiveAvgAllocation,
                               BlockPlan, FixedAllocation)


def _profile(seed: int, d: int, scale: float) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return (np.abs(rng.standard_normal(d)) * scale).astype(np.float32)


def _stats(klp: np.ndarray):
    klp = jnp.asarray(klp)
    return {"profile": klp, "total": jnp.sum(klp)}


def _exact_bits(alloc, klp, d, n_is):
    """Exact host plan's uplink budget: blocks * log2(n_is) + overhead."""
    _, nb, _, oh = alloc.plan(klp, d)
    return nb * math.log2(n_is), oh


class TestDeterminism:
    @settings(max_examples=8)
    @given(st.integers(min_value=64, max_value=2048),
           st.floats(min_value=1e-4, max_value=0.5))
    def test_adaptive_plan_deterministic(self, d, scale):
        alloc = AdaptiveAllocation(n_is=16)
        klp = _profile(0, d, scale)
        a = alloc.plan(klp, d)
        b = alloc.plan(klp.copy(), d)
        assert a[0] == b[0] and a[1] == b[1] and a[3] == b[3]
        np.testing.assert_array_equal(a[2], b[2])

    @settings(max_examples=8)
    @given(st.floats(min_value=1e-4, max_value=0.5))
    def test_adaptive_avg_plan_deterministic(self, scale):
        alloc = AdaptiveAvgAllocation(n_is=16)
        klp = _profile(1, 512, scale)
        assert alloc.plan(klp, 512) == alloc.plan(klp.copy(), 512)

    def test_finalize_plan_deterministic(self):
        alloc = AdaptiveAllocation(n_is=16)
        klp = _profile(2, 512, 0.05)
        tmpl = alloc.bucket_plans(512)[2]
        a = alloc.finalize_plan(tmpl, _stats(klp), 512)
        b = alloc.finalize_plan(tmpl, _stats(klp), 512)
        np.testing.assert_array_equal(np.asarray(a.seg_ids),
                                      np.asarray(b.seg_ids))
        assert int(a.billable) == int(b.billable)


class TestMonotone:
    @settings(max_examples=8)
    @given(st.floats(min_value=1.2, max_value=8.0))
    def test_avg_bucket_monotone_in_kl(self, ratio):
        """Scaling the KL profile up never selects *fewer* blocks."""
        alloc = AdaptiveAvgAllocation(n_is=16, min_block=32, max_block=4096)
        d = 4096
        klp = _profile(3, d, 0.01)
        lo = int(alloc.select_bucket(_stats(klp), d))
        hi = int(alloc.select_bucket(_stats(klp * ratio), d))
        # bucket index orders by *size*; more KL -> smaller-or-equal size
        assert hi <= lo
        sizes = alloc.bucket_sizes()
        assert sizes[hi] <= sizes[lo]

    @settings(max_examples=8)
    @given(st.floats(min_value=1.2, max_value=8.0))
    def test_adaptive_bucket_monotone_in_kl(self, ratio):
        alloc = AdaptiveAllocation(n_is=16)
        d = 2048
        klp = _profile(4, d, 0.01)
        lo = int(alloc.select_bucket(_stats(klp), d))
        hi = int(alloc.select_bucket(_stats(klp * ratio), d))
        grid = alloc.bucket_grid(d)
        assert grid[hi] >= grid[lo]  # more KL -> at least as many blocks

    def test_grid_sorted_and_capped(self):
        alloc = AdaptiveAllocation(min_blocks=4)
        grid = alloc.bucket_grid(2048)
        assert list(grid) == sorted(set(grid))
        assert grid[0] == 4 and grid[-1] == 2048 // 8


class TestConservative:
    @settings(max_examples=8)
    @given(st.floats(min_value=1e-3, max_value=0.5))
    def test_avg_bucket_is_exact_plan(self, scale):
        """AdaptiveAvg: the selected bucket IS the host plan (same pow2
        size), so bucketing adds zero overhead by construction."""
        alloc = AdaptiveAvgAllocation(n_is=16, min_block=32, max_block=4096)
        d = 4096
        klp = _profile(5, d, scale)
        size_exact, nb_exact, _, _ = alloc.plan(klp, d)
        idx = int(alloc.select_bucket(_stats(klp), d))
        plan = alloc.bucket_plans(d)[idx]
        assert plan.size == size_exact and plan.n_blocks == nb_exact
        assert alloc.bucket_overhead_bits == 0.0

    @settings(max_examples=8)
    @given(st.integers(min_value=256, max_value=4096),
           st.floats(min_value=1e-3, max_value=0.3))
    def test_adaptive_bucket_never_exceeds_exact_budget(self, d, scale):
        """Floor rounding: bucketed bits <= exact bits + declared overhead."""
        n_is = 16
        alloc = AdaptiveAllocation(n_is=n_is)
        klp = _profile(6, d, scale)
        exact_bits, exact_oh = _exact_bits(alloc, klp, d, n_is)
        idx = int(alloc.select_bucket(_stats(klp), d))
        plan = alloc.finalize_plan(alloc.bucket_plans(d)[idx], _stats(klp), d)
        bucket_bits = int(plan.billable) * math.log2(n_is)
        assert bucket_bits <= exact_bits + alloc.bucket_overhead_bits
        assert float(plan.overhead_bits) <= exact_oh + alloc.bucket_overhead_bits
        # ... and the static capacity really is the grid's floor:
        grid = alloc.bucket_grid(d)
        _, nb_exact, _, _ = alloc.plan(klp, d)
        assert plan.n_blocks == max(g for g in grid if g <= nb_exact)

    def test_explicit_buckets_respected(self):
        # min_blocks is always in the grid: the conservative floor anchor
        alloc = AdaptiveAllocation(n_is=16, buckets=(40, 10, 20, 10))
        assert alloc.bucket_grid(2048) == (4, 10, 20, 40)
        # out-of-range buckets clamp into [min_blocks, d // 8]
        alloc2 = AdaptiveAllocation(n_is=16, min_blocks=4, buckets=(1, 9999))
        assert alloc2.bucket_grid(256) == (4, 32)

    def test_explicit_buckets_above_exact_stay_conservative(self):
        """A bucket set entirely above the exact block count must floor to
        the min_blocks anchor, never round up onto the grid."""
        n_is = 16
        alloc = AdaptiveAllocation(n_is=n_is, buckets=(64, 128))
        d = 2048
        klp = _profile(8, d, 1e-4)  # tiny KL -> exact plan wants min_blocks
        _, nb_exact, _, _ = alloc.plan(klp, d)
        assert nb_exact < 64
        idx = int(alloc.select_bucket(_stats(klp), d))
        plan = alloc.finalize_plan(alloc.bucket_plans(d)[idx], _stats(klp), d)
        assert plan.n_blocks == alloc.min_blocks
        assert int(plan.billable) * math.log2(n_is) <= \
            nb_exact * math.log2(n_is) + alloc.bucket_overhead_bits


class TestFinalizeMatchesHostPlan:
    def test_seg_ids_match_exact_plan_at_same_count(self):
        """With the bucket capacity equal to the exact block count, the
        traced binning reproduces the host plan's segment ids."""
        d = 1024
        alloc = AdaptiveAllocation(n_is=16)
        klp = _profile(7, d, 0.05)
        _, nb, seg_host, oh_host = alloc.plan(klp, d)
        tmpl = BlockPlan(size=None, n_blocks=nb, seg_ids=None,
                         overhead_bits=0.0)
        plan = alloc.finalize_plan(tmpl, _stats(klp), d)
        np.testing.assert_array_equal(np.asarray(plan.seg_ids), seg_host)
        assert int(plan.billable) == int(seg_host.max()) + 1
        assert float(plan.overhead_bits) == oh_host

    def test_billable_defaults_to_capacity(self):
        plan = BlockPlan(size=64, n_blocks=8, seg_ids=None, overhead_bits=0.0)
        assert plan.billable == 8 and not plan.adaptive
