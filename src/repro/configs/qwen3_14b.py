"""Qwen3 14B: dense GQA with qk-norm.  [hf:Qwen/Qwen3-8B family]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", arch_type="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    long_context_window=4096,  # long_500k runs the SWA variant (DESIGN.md §4)
    source="hf:Qwen/Qwen3-8B (family card)",
)
