"""int8 KV-cache quantization: decode consistency + footprint halving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as T
from repro.models.layers import dequantize_kv, quantize_kv

KEY = jax.random.PRNGKey(51)


def test_quantize_roundtrip_error_bounded():
    t = jax.random.normal(KEY, (2, 8, 4, 32)) * 3.0
    q, s = quantize_kv(t)
    back = dequantize_kv(q, s)
    # symmetric int8: max error ~ scale/2 = max|row|/254
    err = np.abs(np.asarray(back - t))
    bound = np.asarray(jnp.max(jnp.abs(t), -1) / 127.0)[..., None]
    assert (err <= bound * 0.51 + 1e-6).all()


def test_decode_matches_unquantized_argmax():
    """Greedy decode survives int8 KV wherever the decision is decisive.

    int8 perturbs the logits by a bounded noise; argmax invariance is only
    a meaningful guarantee for sequences whose winning margin exceeds that
    noise (with random-init weights the top-2 gap can be ~1e-2, below what
    ANY 8-bit cache could preserve).  So: quantized logits must stay close
    everywhere, and the greedy choice must match for every sequence whose
    unquantized top-2 margin exceeds twice the observed noise -- and the
    test must contain at least one such decisive sequence to bite.
    """
    cfg = C.get("qwen3-1.7b").reduced()
    cfg_q = dataclasses.replace(cfg, kv_cache_quant=True)
    model = T.build(cfg)
    model_q = T.build(cfg_q)
    params, _ = T.init_params(model, jax.random.PRNGKey(0))
    b, s = 2, 10
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (b, s), 0, cfg.vocab)

    cache = T.init_cache(model, b, 16)
    cache_q = T.init_cache(model_q, b, 16)
    for t in range(s):
        lg, cache = T.serve_step(model, params, cache, toks[:, t:t + 1],
                                 jnp.int32(t))
        lq, cache_q = T.serve_step(model_q, params, cache_q, toks[:, t:t + 1],
                                   jnp.int32(t))
    lg32 = np.asarray(lg[:, 0], np.float32)
    lq32 = np.asarray(lq[:, 0], np.float32)
    # group-16 scales + full-precision current token keep the logits close
    np.testing.assert_allclose(lq32, lg32, rtol=0.05, atol=0.06)
    err = np.abs(lq32 - lg32).max()
    top2 = np.sort(lg32, -1)
    decisive = (top2[:, -1] - top2[:, -2]) > 2 * err
    assert decisive.any(), "no decisive sequence -- test would be vacuous"
    a = lg32.argmax(-1)
    aq = lq32.argmax(-1)
    np.testing.assert_array_equal(a[decisive], aq[decisive])


def test_cache_footprint_halved():
    cfg = C.get("qwen3-1.7b").reduced()
    cfg_q = dataclasses.replace(cfg, kv_cache_quant=True)
    b, s = 4, 64

    def nbytes(model):
        cache = jax.eval_shape(lambda: T.init_cache(model, b, s))
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))

    full = nbytes(T.build(dataclasses.replace(cfg, dtype="bfloat16")))
    quant = nbytes(T.build(dataclasses.replace(cfg_q, dtype="bfloat16")))
    assert quant < full * 0.6, (quant, full)  # int8 + small scale overhead
