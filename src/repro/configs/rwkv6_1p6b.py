"""RWKV-6 "Finch" 1.6B: attention-free, data-dependent decay.  [arXiv:2404.05892]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", arch_type="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, head_dim=64,
    block_kind="rwkv6", rope_kind="none",
    source="arXiv:2404.05892 (Finch)",
)
