"""Substrate tests: optimizers, data pipeline, checkpointing, block
allocation, bit accounting, sharding helpers."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import checkpoint, optim
from repro.core.bitmeter import BitMeter
from repro.core.blocks import AdaptiveAllocation, AdaptiveAvgAllocation, FixedAllocation
from repro.data import TokenPipeline, batches_for
from repro.models import sharding
import repro.configs as C

KEY = jax.random.PRNGKey(4)


class TestOptim:
    def _quad(self, opt, steps=200):
        target = jnp.array([1.0, -2.0, 3.0])
        params = jnp.zeros(3)
        state = opt.init(params)
        for _ in range(steps):
            g = 2 * (params - target)
            params, state = opt.update(g, params, state)
        return float(jnp.max(jnp.abs(params - target)))

    def test_sgd_converges(self):
        assert self._quad(optim.sgd(0.1)) < 1e-3

    def test_momentum_converges(self):
        assert self._quad(optim.momentum(0.05)) < 1e-3

    def test_adam_converges(self):
        assert self._quad(optim.adam(0.1), steps=500) < 1e-2

    def test_adafactor_like_converges(self):
        opt = optim.adafactor_like(0.05)
        target = jnp.ones((4, 4))
        params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
        state = opt.init(params)
        for _ in range(400):
            g = {"w": 2 * (params["w"] - target), "b": 2 * params["b"]}
            params, state = opt.update(g, params, state)
        assert float(jnp.max(jnp.abs(params["w"] - target))) < 0.05


class TestTokenPipeline:
    def test_shapes_and_vocab(self):
        pipe = TokenPipeline(1000, seed=0)
        b = pipe.batch(4, 32)
        assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
        assert b["tokens"].max() < 1000 and b["tokens"].min() >= 0

    def test_labels_shifted(self):
        pipe = TokenPipeline(500, seed=1)
        b = pipe.batch(2, 16)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_deterministic_by_seed(self):
        b1 = TokenPipeline(500, seed=3).batch(2, 16)
        b2 = TokenPipeline(500, seed=3).batch(2, 16)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_markov_predictability(self):
        """Low-alpha transition rows must make bigrams predictable (there is
        learnable signal, unlike iid-uniform tokens)."""
        pipe = TokenPipeline(256, seed=0, alpha=0.01)
        b = pipe.batch(8, 512)
        t = b["tokens"]
        # empirical conditional-mode accuracy of next token given current
        pairs = {}
        for row in t:
            for a_, b_ in zip(row[:-1], row[1:]):
                pairs.setdefault(int(a_), {}).setdefault(int(b_), 0)
                pairs[int(a_)][int(b_)] += 1
        hits = sum(max(d.values()) for d in pairs.values())
        total = sum(sum(d.values()) for d in pairs.values())
        assert hits / total > 0.3, hits / total

    def test_modality_extras(self):
        cfg = C.get("hubert-xlarge").reduced()
        b = next(iter(batches_for(cfg, 2, 8, n=1)))
        assert "inputs" in b and b["inputs"].shape == (2, 8, cfg.d_model)
        cfg = C.get("qwen2-vl-72b").reduced()
        b = next(iter(batches_for(cfg, 2, 8, n=1)))
        assert "image_embeds" in b and "positions" in b


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": [jnp.ones((4,), jnp.bfloat16),
                      (jnp.zeros((), jnp.int32), jnp.full((2,), 7.0))]}
        path = str(tmp_path / "ck.bin")
        checkpoint.save(path, tree, step=42)
        restored = checkpoint.restore(path, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert checkpoint.latest_step(path) == 42

    def test_shape_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "ck.bin")
        checkpoint.save(path, {"a": jnp.zeros((2,))})
        with pytest.raises(AssertionError):
            checkpoint.restore(path, {"a": jnp.zeros((3,))})


class TestAllocations:
    def test_fixed_plan(self):
        size, nb, seg, oh = FixedAllocation(128).plan(None, 1000)
        assert size == 128 and nb == 8 and seg is None and oh == 0

    def test_adaptive_avg_tracks_kl(self):
        alloc = AdaptiveAvgAllocation(n_is=256, min_block=32, max_block=4096)
        lo = np.full(4096, 1e-4)   # tiny KL -> big blocks
        hi = np.full(4096, 0.5)    # big KL -> small blocks
        s_lo, *_ = alloc.plan(lo, 4096)
        s_hi, *_ = alloc.plan(hi, 4096)
        assert s_lo > s_hi

    def test_adaptive_equal_mass(self):
        alloc = AdaptiveAllocation(n_is=64)
        kl = np.abs(np.random.default_rng(0).standard_normal(2048)) * 0.01
        _, nb, seg, oh = alloc.plan(kl, 2048)
        assert seg.shape == (2048,)
        assert seg.min() == 0 and seg.max() == nb - 1
        masses = np.bincount(seg, weights=kl)
        assert masses.max() / max(masses.min(), 1e-12) < 20  # roughly equal

    def test_adaptive_overhead_booked(self):
        alloc = AdaptiveAllocation(n_is=64)
        kl = np.full(1024, 0.05)
        _, nb, _, oh = alloc.plan(kl, 1024)
        assert oh == nb * math.ceil(math.log2(alloc.max_block))


class TestBitMeter:
    def test_bpp_normalization(self):
        m = BitMeter(n_clients=4, d=1000)
        m.add_round(4 * 1000.0, 4 * 2000.0)  # 1 bpp up, 2 bpp down
        assert abs(m.uplink_bpp - 1.0) < 1e-9
        assert abs(m.downlink_bpp - 2.0) < 1e-9
        assert abs(m.total_bpp - 3.0) < 1e-9
        assert abs(m.total_bpp_bc - 1.5) < 1e-9  # downlink / n

    def test_pr_no_broadcast_gain(self):
        m = BitMeter(n_clients=4, d=1000, broadcast_downlink_shareable=False)
        m.add_round(0.0, 4000.0)
        assert abs(m.total_bpp_bc - m.total_bpp) < 1e-12


class TestShardingHelpers:
    def test_sanitize_drops_nondividing(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sharding.set_mesh(mesh)
        try:
            sp = sharding.sanitize((3, 5), P("data", "model"))
            assert sp == P("data", "model")  # axis size 1 divides all
        finally:
            sharding.set_mesh(None)

    def test_constraint_noop_without_mesh(self):
        sharding.set_mesh(None)
        x = jnp.ones((4, 4))
        y = sharding.constraint(x, P("data", None))
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_fsdp_specs_large_leaves_only(self):
        from repro.models import transformer as T
        cfg = C.get("qwen3-1.7b").reduced()
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sharding.set_mesh(mesh)
        try:
            model = T.build(cfg)
            sds, specs = T.abstract_init(model)
            refined = T.fsdp_specs(sds, specs, min_size=16)
            flat_r = jax.tree.leaves(refined, is_leaf=lambda t: isinstance(t, P))
            flat_s = jax.tree.leaves(specs, is_leaf=lambda t: isinstance(t, P))
            assert len(flat_r) == len(flat_s)
        finally:
            sharding.set_mesh(None)


class TestPlanGroups:
    def test_uniform_dense(self):
        from repro.models import transformer as T
        cfg = C.get("qwen3-14b")
        prefix, pattern, n_rep = T.plan_groups(cfg)
        assert prefix == [] and pattern == [("attn", "dense")] and n_rep == 40

    def test_kimi_prefix(self):
        from repro.models import transformer as T
        cfg = C.get("kimi-k2-1t-a32b")
        prefix, pattern, n_rep = T.plan_groups(cfg)
        assert prefix == [("attn", "dense")]
        assert pattern == [("attn", "moe")] and n_rep == 60

    def test_jamba_period8(self):
        from repro.models import transformer as T
        cfg = C.get("jamba-v0.1-52b")
        prefix, pattern, n_rep = T.plan_groups(cfg)
        assert len(pattern) == 8 and n_rep == 4
        assert pattern[4][0] == "attn"           # attn at offset 4
        assert sum(1 for p in pattern if p[0] == "attn") == 1  # 1:7 ratio
        assert sum(1 for p in pattern if p[1] == "moe") == 4   # every 2nd

    def test_plan_covers_all_layers(self):
        from repro.models import transformer as T
        for a in C.ARCH_IDS:
            cfg = C.get(a)
            prefix, pattern, n_rep = T.plan_groups(cfg)
            assert len(prefix) + len(pattern) * n_rep == cfg.n_layers
