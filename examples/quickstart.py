"""Quickstart: BiCompFL-GR on a synthetic federated task in ~a minute.

    PYTHONPATH=src python examples/quickstart.py

Ten clients collaboratively train a probabilistic mask over a frozen
signed-constant MLP; all communication runs through bi-directional MRC.
Prints per-round accuracy and the communication bill (bits per parameter),
which lands orders of magnitude below dense FedAvg's 64 bpp.
"""
import time

import jax

from repro.core.blocks import FixedAllocation
from repro.fl.data import make_synthetic, partition_iid
from repro.fl.engine import FLEngine
from repro.fl.registry import bicompfl_spec
from repro.fl.nets import make_mlp
from repro.fl.tasks import make_mask_task


def main():
    key = jax.random.PRNGKey(0)
    train, test = make_synthetic(key, n_train=2000, n_test=500, hw=10, noise=0.4)
    n_clients = 10
    shards = partition_iid(jax.random.fold_in(key, 1), train, n_clients,
                           2000 // n_clients)

    net = make_mlp(in_dim=100, widths=(256,), signed_constant=True)
    task = make_mask_task(net, jax.random.fold_in(key, 2), test.x, test.y,
                          local_epochs=3, lr=0.1)
    print(f"model dimension d = {task.d} Bernoulli parameters")

    # A scheme is (uplink channel, downlink channel, aggregator): the GR
    # variant is an MRC uplink over shared candidates + an index-relay
    # downlink.  Swap either channel to explore new scenarios (DESIGN.md).
    spec = bicompfl_spec("GR", allocation=FixedAllocation(128), n_is=64,
                         n_dl=n_clients)
    t0 = time.time()
    out = FLEngine(task, spec).run(shards, rounds=15, seed=0, eval_every=3)
    for h in out["history"]:
        print(f"round {h['round']:3d}  acc {h['acc']:.3f}  "
              f"cumulative bpp {h['bpp_so_far']:.4f}")
    m = out["meter"]
    print(f"\nfinal acc {out['final_acc']:.3f}   max acc {out['max_acc']:.3f}")
    print(f"bitrate: {m['bpp']:.4f} bpp (vs 64 bpp dense FedAvg -> "
          f"{64 / m['bpp']:.0f}x reduction)   [{time.time()-t0:.0f}s]")


if __name__ == "__main__":
    main()
