"""Qwen3 1.7B: dense GQA with qk-norm.  [hf:Qwen/Qwen3-8B family]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", arch_type="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    long_context_window=4096,
    source="hf:Qwen/Qwen3-8B (family card)",
)
