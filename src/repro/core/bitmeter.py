"""Communication accounting (bits, bpp) for all schemes.

Conventions follow the paper's tables (Appendix I):

* bpp columns are *per client, per parameter, per global round*;
* total bpp = uplink + downlink;
* bpp (BC): when a broadcast downlink exists, the downlink of every scheme
  whose downlink payload is identical for all clients is divided by n
  (BiCompFL-PR cannot profit -- its downlink is client-specific).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


class ReconcileError(AssertionError):
    """Booked bits diverge from a serialized wire stream (loud by design)."""


@dataclass
class BitMeter:
    """Accumulates uplink/downlink bits over rounds for one scheme."""

    n_clients: int
    d: int
    broadcast_downlink_shareable: bool = True  # False for PR-style downlinks
    uplink_bits: float = 0.0    # summed over clients and rounds
    downlink_bits: float = 0.0  # summed over clients and rounds
    retransmit_bits: float = 0.0  # corrupted-in-flight copies (both links)
    rounds: int = 0
    history: List[Dict[str, float]] = field(default_factory=list)

    def add_round(self, uplink_bits_total: float, downlink_bits_total: float,
                  overhead_bits: float = 0.0,
                  retransmit_bits: float = 0.0) -> None:
        """Book one global round. Totals are summed across clients.

        ``retransmit_bits`` are payload bits of frame copies that were
        corrupted in flight and had to be resent (or were lost after the
        retry budget): they count toward ``total_bits`` -- the real price
        of an unreliable link -- but never toward the per-direction
        *useful* payload totals the wire stream reconciles.
        """
        self.uplink_bits += uplink_bits_total + overhead_bits
        self.downlink_bits += downlink_bits_total
        self.retransmit_bits += retransmit_bits
        self.rounds += 1
        entry = {
            "round": self.rounds,
            "uplink_bits": uplink_bits_total + overhead_bits,
            "downlink_bits": downlink_bits_total,
            "cum_bits": self.uplink_bits + self.downlink_bits
            + self.retransmit_bits,
        }
        if retransmit_bits:
            entry["retransmit_bits"] = retransmit_bits
        self.history.append(entry)

    def book_run(self, uplink_bits, downlink_bits, overhead_bits=0.0,
                 retransmit_bits=0.0, snapshot_mask=None):
        """Book a whole run's rounds in one call (per-round total sequences).

        Used after a fused (device-resident) execution.  With a static
        block plan the per-round bit totals are data-independent Python
        floats and the meter replays them host-side with the same per-round
        float arithmetic as the host loop; with a bucketed adaptive plan
        the engine hands over the traced per-round bits vectors that came
        out of the scan.  ``overhead_bits`` is either one per-round scalar
        or a per-round sequence (the adaptive side-information varies with
        the round's plan).  Returns the ``(total_bits, total_bpp)``
        snapshot after each round where ``snapshot_mask`` is True (every
        round when None) -- the values the engine's history entries record
        at evaluation rounds.
        """
        per_round_overhead = hasattr(overhead_bits, "__len__")
        per_round_retrans = hasattr(retransmit_bits, "__len__")
        snaps = []
        for t, (u, dl) in enumerate(zip(uplink_bits, downlink_bits)):
            oh = overhead_bits[t] if per_round_overhead else overhead_bits
            rt = retransmit_bits[t] if per_round_retrans else retransmit_bits
            self.add_round(float(u), float(dl), overhead_bits=float(oh),
                           retransmit_bits=float(rt))
            if snapshot_mask is None or snapshot_mask[t]:
                snaps.append((self.total_bits, self.total_bpp))
        return snaps

    # --- per-client per-param per-round averages (the table columns) -----
    def _per(self, bits: float) -> float:
        if self.rounds == 0:
            return 0.0
        return bits / (self.n_clients * self.d * self.rounds)

    @property
    def uplink_bpp(self) -> float:
        return self._per(self.uplink_bits)

    @property
    def downlink_bpp(self) -> float:
        return self._per(self.downlink_bits)

    @property
    def retransmit_bpp(self) -> float:
        return self._per(self.retransmit_bits)

    @property
    def total_bpp(self) -> float:
        return self.uplink_bpp + self.downlink_bpp + self.retransmit_bpp

    @property
    def total_bpp_bc(self) -> float:
        """Total bpp when a broadcast downlink channel is available."""
        dl = self.downlink_bpp
        if self.broadcast_downlink_shareable:
            dl = dl / self.n_clients
        return self.uplink_bpp + dl + self.retransmit_bpp

    @property
    def total_bits(self) -> float:
        return self.uplink_bits + self.downlink_bits + self.retransmit_bits

    def reconcile(self, uplink_stream_bits: float,
                  downlink_stream_bits: float, *,
                  retransmit_stream_bits: float = 0.0,
                  framing_bits: float = 0.0,
                  n_messages: int = 0, frame_overhead_bits: int = 0,
                  tol_bits: float = 0.0,
                  rel_tol: float = 1e-9) -> Dict[str, float]:
        """Audit booked bits against serialized stream lengths.

        ``uplink_stream_bits`` / ``downlink_stream_bits`` are the summed
        *payload* bits of a wire stream per direction (framing excluded);
        they must match the booked per-direction totals within ``tol_bits``
        plus a ``rel_tol`` relative slack for float64 bookkeeping round-off
        (the codecs themselves are exact -- see repro.wire.frame for the
        tolerance contract).  ``retransmit_stream_bits`` are the summed
        payload bits of corrupted-in-flight frame copies and must match
        the booked ``retransmit_bits`` the same way.  When framing figures
        are supplied, the framing overhead must lie within the per-message
        envelope ``[n_messages * frame_overhead_bits,
        n_messages * (frame_overhead_bits + 7)]`` (header + CRC trailer +
        <8 pad bits).  Raises :class:`ReconcileError` on any divergence;
        returns the audit report otherwise.
        """
        def check(link: str, booked: float, stream: float) -> float:
            err = abs(booked - stream)
            tol = tol_bits + rel_tol * max(abs(booked), abs(stream))
            if err > tol:
                raise ReconcileError(
                    f"{link} booked {booked} bits but the wire stream "
                    f"carries {stream} payload bits (|diff| {err} > "
                    f"tolerance {tol})")
            return err

        up_err = check("uplink", self.uplink_bits, uplink_stream_bits)
        dn_err = check("downlink", self.downlink_bits, downlink_stream_bits)
        rt_err = check("retransmit", self.retransmit_bits,
                       retransmit_stream_bits)
        if n_messages:
            lo = n_messages * frame_overhead_bits
            hi = n_messages * (frame_overhead_bits + 7)
            if not lo <= framing_bits <= hi:
                raise ReconcileError(
                    f"framing overhead {framing_bits} bits outside "
                    f"[{lo}, {hi}] for {n_messages} messages of "
                    f"{frame_overhead_bits}-bit frame overhead")
        return {
            "uplink_booked_bits": self.uplink_bits,
            "uplink_stream_bits": uplink_stream_bits,
            "uplink_err_bits": up_err,
            "downlink_booked_bits": self.downlink_bits,
            "downlink_stream_bits": downlink_stream_bits,
            "downlink_err_bits": dn_err,
            "retransmit_booked_bits": self.retransmit_bits,
            "retransmit_stream_bits": retransmit_stream_bits,
            "retransmit_err_bits": rt_err,
            "framing_bits": framing_bits,
            "n_messages": n_messages,
        }

    def summary(self) -> Dict[str, float]:
        return {
            "bpp": self.total_bpp,
            "bpp_bc": self.total_bpp_bc,
            "uplink_bpp": self.uplink_bpp,
            "downlink_bpp": self.downlink_bpp,
            "retransmit_bpp": self.retransmit_bpp,
            "total_bits": self.total_bits,
            "retransmit_bits": self.retransmit_bits,
            "rounds": self.rounds,
        }
