"""Local-training tasks: the client-side optimization step of the FL loop.

* ``MaskTask``: FedPM-style probabilistic mask training (paper Appendix G).
  The model is a vector theta in [0,1]^d of Bernoulli parameters over a
  *fixed* randomly-initialized network w.  Local training is mirror descent:
  map theta to dual scores s = sigma^{-1}(theta), take L SGD passes on s with
  the straight-through estimator through the Bernoulli sampling, map back.
  The KL-proximity geometry of this update is exactly what makes the MRC
  uplink cheap (communication cost ~ d_KL(q || theta_hat)).

* ``CFLTask``: conventional FL.  Local training runs L epochs of Adam/SGD
  from the client's model estimate and returns the model *delta* (the
  "gradient" that the compressors quantize).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.bernoulli import clip01, inv_sigmoid
from .nets import Net, accuracy, cross_entropy, flatten_weights


@dataclass(eq=False)  # hashable by identity: methods are jitted with static self
class MaskTask:
    net: Net
    w0_flat: jax.Array          # fixed signed-constant weights, flattened
    unravel: Callable
    x_test: jax.Array
    y_test: jax.Array
    local_epochs: int = 3
    batch_size: int = 128
    lr: float = 0.1   # paper: Adam in score space with lr 0.1
    optimizer: str = "adam"  # adam | sgd -- Adam is essential: averaged
                             # binary masks saturate theta at {0, 1} where
                             # sigmoid gradients vanish; Adam renormalizes
    theta_init: float = 0.5

    @property
    def d(self) -> int:
        return int(self.w0_flat.shape[0])

    def init_theta(self) -> jax.Array:
        return jnp.full((self.d,), self.theta_init, jnp.float32)

    # -- client step ------------------------------------------------------
    @functools.partial(jax.jit, static_argnums=0)
    def local_train(self, theta: jax.Array, xs: jax.Array, ys: jax.Array, key: jax.Array):
        """L epochs of score-space SGD with STE; returns the posterior q."""
        shard = xs.shape[0]
        bs = min(self.batch_size, shard)
        steps_per_epoch = max(shard // bs, 1)
        n_steps = self.local_epochs * steps_per_epoch
        kb, km = jax.random.split(key)
        batch_idx = jax.random.randint(kb, (n_steps, bs), 0, shard)

        def loss_fn(s, xb, yb, mk):
            prob = jax.nn.sigmoid(s)
            m = jax.random.bernoulli(mk, prob).astype(jnp.float32)
            m_ste = m + prob - jax.lax.stop_gradient(prob)  # straight-through
            weights = self.unravel(self.w0_flat * m_ste)
            return cross_entropy(self.net.apply(weights, xb), yb)

        opt = optim.adam(self.lr) if self.optimizer == "adam" else optim.sgd(self.lr)

        def step(carry, inp):
            s, st = carry
            idx, mk = inp
            g = jax.grad(loss_fn)(s, xs[idx], ys[idx], mk)
            s, st = opt.update(g, s, st)
            return (s, st), ()

        s0 = inv_sigmoid(theta)
        mks = jax.random.split(km, n_steps)
        (s_fin, _), _ = jax.lax.scan(step, (s0, opt.init(s0)), (batch_idx, mks))
        return clip01(jax.nn.sigmoid(s_fin))

    # -- evaluation -------------------------------------------------------
    def evaluate(self, theta: jax.Array) -> float:
        """Accuracy with the expected mask (w * theta) -- low-variance eval."""
        weights = self.unravel(self.w0_flat * theta)
        return accuracy(self.net.apply, weights, self.x_test, self.y_test)

    def evaluate_sampled(self, theta: jax.Array, key: jax.Array) -> float:
        m = jax.random.bernoulli(key, clip01(theta)).astype(jnp.float32)
        weights = self.unravel(self.w0_flat * m)
        return accuracy(self.net.apply, weights, self.x_test, self.y_test)


def make_mask_task(net: Net, key: jax.Array, x_test, y_test, **kw) -> MaskTask:
    w0 = net.init(key)
    w0_flat, unravel = flatten_weights(w0)
    return MaskTask(net=net, w0_flat=w0_flat, unravel=unravel,
                    x_test=x_test, y_test=y_test, **kw)


@dataclass(eq=False)
class CFLTask:
    net: Net
    unravel: Callable
    d: int
    x_test: jax.Array
    y_test: jax.Array
    local_epochs: int = 3
    batch_size: int = 128
    local_lr: float = 3e-4
    optimizer: str = "adam"

    @functools.partial(jax.jit, static_argnums=0)
    def local_train(self, theta: jax.Array, xs: jax.Array, ys: jax.Array, key: jax.Array):
        """Return the local model delta ("gradient") after L epochs."""
        shard = xs.shape[0]
        bs = min(self.batch_size, shard)
        steps_per_epoch = max(shard // bs, 1)
        n_steps = self.local_epochs * steps_per_epoch
        batch_idx = jax.random.randint(key, (n_steps, bs), 0, shard)

        opt = optim.adam(self.local_lr) if self.optimizer == "adam" else optim.sgd(self.local_lr)

        def loss_fn(w, xb, yb):
            return cross_entropy(self.net.apply(self.unravel(w), xb), yb)

        def step(carry, idx):
            w, st = carry
            g = jax.grad(loss_fn)(w, xs[idx], ys[idx])
            w, st = opt.update(g, w, st)
            return (w, st), ()

        (w_fin, _), _ = jax.lax.scan(step, (theta, opt.init(theta)), batch_idx)
        return theta - w_fin  # "gradient" = negative update direction

    def evaluate(self, theta: jax.Array) -> float:
        return accuracy(self.net.apply, self.unravel(theta), self.x_test, self.y_test)


def make_cfl_task(net: Net, key: jax.Array, x_test, y_test, **kw) -> Tuple[CFLTask, jax.Array]:
    w0 = net.init(key)
    w0_flat, unravel = flatten_weights(w0)
    task = CFLTask(net=net, unravel=unravel, d=int(w0_flat.shape[0]),
                   x_test=x_test, y_test=y_test, **kw)
    return task, w0_flat
