"""Deterministic fault injection for the FL engine (cf. DESIGN.md §8).

Cross-device FL is not a perfect world: clients drop out mid-round,
stragglers miss the aggregation deadline, and physical links corrupt
frames.  This module makes all of that *deterministic and seeded*, the
same way the engine's ``cohort_schedule`` is: a :class:`FaultPlan` is
pure configuration, and :meth:`FaultPlan.schedule` precomputes every
fault of an R-round run as numpy tables **before** the run starts.  Both
engine paths consume the same tables -- the host loop reads them as
Python values, the fused path feeds them into the ``lax.scan`` as traced
masks -- so the same seed produces the *identical* fault trajectory in
``mode="host"`` and ``mode="fused"``, and a fault schedule can be
replayed, resumed mid-run, or audited without ever re-running training.

Fault taxonomy (per round t, per client i):

* **dropout** -- the client is offline for the whole round: it sends no
  uplink, receives no downlink, and its ``theta_hat`` row / EF-state row
  stay at their pre-round values (carried, not corrupted);
* **straggler** -- the client trains and transmits, but past the
  aggregation deadline: its uplink bits are billed (the traffic
  happened) yet its contribution is *excluded* from the aggregate; it
  still receives the downlink;
* **corruption** -- a delivery (one client's uplink bundle, or one
  recipient's downlink bundle) is hit by ``k`` corrupted frame copies
  before a clean one arrives.  Each corrupted copy is retransmitted
  (bounded by ``max_retries``, with exponential backoff recorded per
  round); ``k > max_retries`` means the delivery is **lost** -- the
  sender behaves like a straggler (uplink) or keeps its stale model
  (downlink).  Every corrupted copy's payload bits are booked into the
  BitMeter's ``retransmit_bits`` category.

All randomness is drawn from one ``numpy.random.default_rng`` stream in
a fixed order, as raw uniforms that thresholds/quantiles are applied to,
so the dropout pattern of ``seed=s`` does not change when
``corrupt_rate`` moves (and vice versa): fault dimensions are
independently reproducible.

Control traffic is modeled as protected: block-plan (CTRL) headers and
EF flush broadcasts ride reliable signaling and are never corrupted;
dropped clients still miss them (the engine scales their booking by the
online fraction).
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass
from typing import Any, Dict, List, Optional

import numpy as np


def _geom_failures(u: np.ndarray, p: float, cap: int) -> np.ndarray:
    """Corrupted copies before the first clean one, each copy bad w.p. p.

    Geometric inverse CDF derived from raw uniforms, so the same ``u``
    maps monotonically to failure counts as ``p`` moves:
    ``P[F >= k] = p^k``, hence ``F = floor(log(1-u) / log(p))``, capped
    at ``cap`` (= max_retries + 1, the "lost" bucket).
    """
    if p <= 0.0:
        return np.zeros(u.shape, dtype=np.int64)
    if p >= 1.0:
        return np.full(u.shape, cap, dtype=np.int64)
    f = np.floor(np.log1p(-u) / math.log(p)).astype(np.int64)
    return np.minimum(f, cap)


@dataclass(frozen=True)
class FaultPlan:
    """Seeded fault configuration; pure data, hashable, reusable."""

    drop_rate: float = 0.0        # P[client offline for a round]
    straggler_rate: float = 0.0   # P[online client misses the deadline]
    corrupt_rate: float = 0.0     # P[one frame copy corrupted in flight]
    max_retries: int = 3          # corrupted copies tolerated per delivery
    backoff_base_s: float = 0.05  # first retry delay (seconds, recorded)
    backoff_factor: float = 2.0   # delay multiplier per further retry
    seed: int = 0

    def __post_init__(self):
        for name in ("drop_rate", "straggler_rate", "corrupt_rate"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{name}={v} outside [0, 1)")
        if self.max_retries < 0:
            raise ValueError(f"max_retries={self.max_retries} < 0")
        if self.backoff_base_s < 0 or self.backoff_factor < 1.0:
            raise ValueError("backoff must be nonnegative and nondecreasing")

    @property
    def trivial(self) -> bool:
        """True when this plan can never produce a fault."""
        return (self.drop_rate == 0.0 and self.straggler_rate == 0.0
                and self.corrupt_rate == 0.0)

    def backoff_s(self, n_failures: int) -> float:
        """Total backoff delay a delivery with ``n_failures`` retries paid."""
        return sum(self.backoff_base_s * self.backoff_factor ** j
                   for j in range(int(n_failures)))

    def schedule(self, rounds: int, n: int) -> "FaultSchedule":
        """Precompute the full fault trajectory (fixed draw order)."""
        rng = np.random.default_rng(self.seed + 0xFA17)
        u_drop = rng.random((rounds, n))
        u_straggle = rng.random((rounds, n))
        u_up = rng.random((rounds, n))
        u_dn = rng.random((rounds, n))
        # One uniform per potential frame copy: the corrupted bit position
        # of attempt a on link l (0=up, 1=down) of client i in round t.
        u_flip = rng.random((rounds, n, 2, self.max_retries + 2))
        cap = self.max_retries + 1
        return FaultSchedule(
            plan=self,
            rounds=rounds, n=n,
            drop=u_drop < self.drop_rate,
            straggle=u_straggle < self.straggler_rate,
            up_failures=_geom_failures(u_up, self.corrupt_rate, cap),
            dn_failures=_geom_failures(u_dn, self.corrupt_rate, cap),
            flip_u=u_flip)


@dataclass(frozen=True)
class FaultSchedule:
    """The precomputed fault tables of one run (numpy, host-resident)."""

    plan: FaultPlan
    rounds: int
    n: int
    drop: np.ndarray         # (rounds, n) bool: offline whole round
    straggle: np.ndarray     # (rounds, n) bool: missed deadline (if online)
    up_failures: np.ndarray  # (rounds, n) int: corrupted uplink copies
    dn_failures: np.ndarray  # (rounds, n) int: corrupted downlink copies
    flip_u: np.ndarray       # (rounds, n, 2, max_retries+2) bit-flip draws

    def round_view(self, t: int, active: np.ndarray,
                   dl_recipients: str = "all") -> "RoundFaults":
        """Resolve round ``t``'s tables against its cohort.

        ``dl_recipients`` is the downlink channel's audience: ``"all"``
        (broadcast-style, every client holds a theta_hat estimate) or
        ``"active"`` (client-specific payloads for the cohort only,
        e.g. the PR downlink).
        """
        if dl_recipients not in ("all", "active"):
            raise ValueError(dl_recipients)
        n = self.n
        mr = self.plan.max_retries
        in_cohort = np.zeros(n, dtype=bool)
        in_cohort[np.asarray(active, dtype=np.int64)] = True
        online = ~self.drop[t]
        senders = in_cohort & online
        up_lost = self.up_failures[t] > mr
        delivered_up = senders & ~up_lost
        contrib = delivered_up & ~self.straggle[t]
        up_wasted = np.where(senders,
                             np.minimum(self.up_failures[t], mr + 1), 0)
        nominal_recv = in_cohort if dl_recipients == "active" \
            else np.ones(n, dtype=bool)
        recv_sched = nominal_recv & online
        all_failed = not bool(contrib.any())
        if all_failed:
            # The server aborts the round before any broadcast: no
            # downlink traffic, clean or wasted, leaves the federator.
            delivered_dn = np.zeros(n, dtype=bool)
            dn_wasted = np.zeros(n, dtype=np.int64)
        else:
            delivered_dn = recv_sched & (self.dn_failures[t] <= mr)
            dn_wasted = np.where(recv_sched,
                                 np.minimum(self.dn_failures[t], mr + 1), 0)
        return RoundFaults(
            t=t, plan=self.plan, active=np.asarray(active, dtype=np.int64),
            in_cohort=in_cohort, online=online, senders=senders,
            delivered_up=delivered_up, contrib=contrib, up_wasted=up_wasted,
            nominal_recv=nominal_recv, delivered_dn=delivered_dn,
            dn_wasted=dn_wasted, all_failed=all_failed)

    def run_views(self, schedule: np.ndarray,
                  dl_recipients: str = "all") -> List["RoundFaults"]:
        """Round views for a whole cohort schedule (rounds, n_active)."""
        return [self.round_view(t, schedule[t], dl_recipients)
                for t in range(min(self.rounds, len(schedule)))]

    def flip_bit(self, t: int, client: int, link: int, attempt: int,
                 nbits: int) -> int:
        """Deterministic corrupted-bit position for one frame copy."""
        u = self.flip_u[t, client, link, min(attempt,
                                             self.flip_u.shape[-1] - 1)]
        return min(int(u * nbits), nbits - 1)


@dataclass(frozen=True)
class RoundFaults:
    """One round's resolved fault view (all masks over global client ids)."""

    t: int
    plan: FaultPlan
    active: np.ndarray        # cohort ids (sorted, from cohort_schedule)
    in_cohort: np.ndarray     # (n,) bool
    online: np.ndarray        # (n,) bool: not dropped this round
    senders: np.ndarray       # (n,) bool: cohort members that transmit
    delivered_up: np.ndarray  # (n,) bool: uplink bundle arrived clean
    contrib: np.ndarray       # (n,) bool: counted into the aggregate
    up_wasted: np.ndarray     # (n,) int: corrupted uplink copies billed
    nominal_recv: np.ndarray  # (n,) bool: downlink audience (no faults)
    delivered_dn: np.ndarray  # (n,) bool: downlink bundle arrived clean
    dn_wasted: np.ndarray     # (n,) int: corrupted downlink copies billed
    all_failed: bool          # zero contributors: the round aborts

    @property
    def faulty(self) -> bool:
        """Anything at all deviated from the fault-free round."""
        return (not bool(self.delivered_up[self.in_cohort].all())
                or bool((self.straggled).any())
                or int(self.up_wasted.sum()) > 0
                or int(self.dn_wasted.sum()) > 0
                or not bool(self.delivered_dn[self.nominal_recv].all()))

    @property
    def dropped(self) -> np.ndarray:
        return self.in_cohort & ~self.online

    @property
    def straggled(self) -> np.ndarray:
        return self.delivered_up & ~self.contrib

    @property
    def lost_up(self) -> np.ndarray:
        return self.senders & ~self.delivered_up

    @property
    def lost_dn(self) -> np.ndarray:
        return self.nominal_recv & self.online & ~self.delivered_dn \
            if not self.all_failed else np.zeros_like(self.online)

    # -- booking fractions (engine-side bit scaling) ----------------------

    @property
    def up_weight(self) -> np.ndarray:
        """(n_active,) f32 aggregation weights over cohort positions."""
        return self.contrib[self.active].astype(np.float32)

    def up_scale(self, n_active: int) -> float:
        """Delivered fraction of the nominal uplink total."""
        return float(self.delivered_up.sum()) / n_active

    def up_retrans_scale(self, n_active: int) -> float:
        return float(self.up_wasted.sum()) / n_active

    def dn_scale(self, denom: int) -> float:
        return float(self.delivered_dn.sum()) / denom

    def dn_retrans_scale(self, denom: int) -> float:
        return float(self.dn_wasted.sum()) / denom

    def overhead_scale(self) -> float:
        """Online fraction: CTRL side information reaches online clients."""
        return float(self.online.sum()) / len(self.online)

    @property
    def backoff_s(self) -> float:
        """Total retry backoff delay recorded for this round (seconds)."""
        return sum(self.plan.backoff_s(int(k))
                   for k in np.concatenate([self.up_wasted, self.dn_wasted])
                   if k)

    def event(self, retransmit_bits: float = 0.0) -> Optional[Dict[str, Any]]:
        """Event-log entry for ``out["faults"]``; None for clean rounds."""
        if not self.faulty and not self.all_failed:
            return None
        ids = np.arange(len(self.online))
        return {
            "round": self.t,
            "dropped": ids[self.dropped].tolist(),
            "stragglers": ids[self.straggled].tolist(),
            "lost_uplink": ids[self.lost_up].tolist(),
            "lost_downlink": ids[self.lost_dn].tolist(),
            "retransmits_up": int(self.up_wasted.sum()),
            "retransmits_down": int(self.dn_wasted.sum()),
            "retransmit_bits": float(retransmit_bits),
            "backoff_s": float(self.backoff_s),
            "survivors": int(self.contrib.sum()),
            "all_failed": bool(self.all_failed),
        }


def fault_report(plan: FaultPlan, views: List[RoundFaults],
                 retransmit_by_round) -> Dict[str, Any]:
    """Assemble ``out["faults"]``: config + event log + run summary.

    Built purely from the precomputed schedule and the engine's per-round
    retransmit bookings, so host and fused runs produce the identical
    report by construction.
    """
    events = []
    for rf in views:
        ev = rf.event(retransmit_bits=retransmit_by_round[rf.t]
                      if retransmit_by_round is not None else 0.0)
        if ev is not None:
            events.append(ev)
    return {
        "plan": asdict(plan),
        "events": events,
        "summary": {
            "rounds": len(views),
            "faulty_rounds": len(events),
            "all_failed_rounds": sum(e["all_failed"] for e in events),
            "dropped_total": sum(len(e["dropped"]) for e in events),
            "stragglers_total": sum(len(e["stragglers"]) for e in events),
            "lost_uplink_total": sum(len(e["lost_uplink"]) for e in events),
            "lost_downlink_total": sum(len(e["lost_downlink"])
                                       for e in events),
            "retransmits_total": sum(e["retransmits_up"]
                                     + e["retransmits_down"]
                                     for e in events),
            "retransmit_bits_total": sum(e["retransmit_bits"]
                                         for e in events),
            "backoff_s_total": sum(e["backoff_s"] for e in events),
        },
    }


def corrupt_copy(frame_bytes: bytes, bitpos: int) -> bytes:
    """One corrupted wire copy of a frame: ``bitpos`` flipped (MSB-first)."""
    out = bytearray(frame_bytes)
    out[bitpos // 8] ^= 0x80 >> (bitpos % 8)
    return bytes(out)
