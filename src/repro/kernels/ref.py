"""Pure-jnp oracles for the Pallas kernels (the correctness reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bernoulli import clip01


def mrc_logw_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Importance log-weights for MRC.

    x: (NB, NIS, S) candidate bits in {0,1} (float)
    a: (NB, S)      log-ratio slope  log(q/p) - log((1-q)/(1-p))
    b: (NB, S)      log-ratio offset log((1-q)/(1-p))
    returns (NB, NIS):  logW[nb, i] = sum_s x[nb,i,s]*a[nb,s] + b[nb,s]
    """
    return jnp.einsum("bis,bs->bi", x, a) + jnp.sum(b, axis=-1, keepdims=True)


def flash_attention_ref(q, k, v, *, causal: bool, window: int = 0,
                        scale: float = 1.0) -> jnp.ndarray:
    """Naive softmax attention oracle.

    q: (BH, Sq, Dh); k, v: (BH, Skv, Dh); returns (BH, Sq, Dh).
    """
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    sq, skv = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def bernoulli_kl_ref(q: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """Per-block summed Bernoulli KL.

    q, p: (NB, S) Bernoulli parameters; returns (NB,) nats.
    """
    q = clip01(q)
    p = clip01(p)
    kl = q * (jnp.log(q) - jnp.log(p)) + (1 - q) * (jnp.log1p(-q) - jnp.log1p(-p))
    return jnp.sum(kl, axis=-1)
