"""Segment-logW Pallas kernel: interpret-mode parity vs the jnp route.

The kernel (``repro.kernels.segment_logw``) must emit the same
(n_is, n_seg) weight matrix as ``repro.core.mrc.default_segment_logw``
(vmapped ``segment_sum``) up to f32 grouping order -- over arbitrary
segmentations including the degenerate single-segment and all-singleton
shapes -- and the pluggable ``seg_logw_fn`` hook must leave the
``encode_segments`` output unchanged end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import mrc
from repro.core.bernoulli import bern_kl, clip01, log_ratio_coeffs
from repro.kernels import ops
from repro.kernels.segment_logw import (NSEG_LANE, TILE_D, TILE_I,
                                        segment_logw_pallas)

KEY = jax.random.PRNGKey(0)


def _random_segmentation(rng, d):
    """A random non-decreasing segmentation of [0, d): (seg_ids, n_seg)."""
    n_cuts = int(rng.integers(0, d))
    if d > 1 and n_cuts:
        cuts = np.sort(rng.choice(np.arange(1, d), size=min(n_cuts, d - 1),
                                  replace=False))
    else:
        cuts = np.array([], dtype=np.int64)
    lengths = np.diff(np.concatenate([[0], cuts, [d]]))
    seg = np.repeat(np.arange(lengths.size), lengths)
    return jnp.asarray(seg, jnp.int32), lengths.size


def _inputs(seed, n_is, d):
    k = jax.random.fold_in(KEY, seed)
    k1, k2, k3 = jax.random.split(k, 3)
    u = mrc._segment_candidates(k1, n_is, d)
    q = clip01(jax.random.uniform(k2, (d,), minval=0.02, maxval=0.98))
    p = clip01(jax.random.uniform(k3, (d,), minval=0.02, maxval=0.98))
    a, b = log_ratio_coeffs(q, p)
    return u, p, a, b


def _assert_parity(n_is, d, seg, n_seg, seed=0):
    u, p, a, b = _inputs(seed, n_is, d)
    ref = mrc.default_segment_logw(u, p, a, b, seg, n_seg)
    out = ops.segment_logw(u, p, a, b, seg, n_seg=n_seg, interpret=True)
    assert out.shape == (n_is, n_seg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


class TestKernelParity:
    @given(st.integers(0, 10**6), st.integers(1, 150), st.integers(1, 200))
    @settings(max_examples=10, deadline=None)
    def test_matches_jnp_route(self, seed, n_is, d):
        seg, n_seg = _random_segmentation(np.random.default_rng(seed), d)
        _assert_parity(n_is, d, seg, n_seg, seed=seed)

    def test_single_segment(self):
        d = 70
        _assert_parity(12, d, jnp.zeros((d,), jnp.int32), 1, seed=1)

    def test_all_singletons(self):
        d = 40
        _assert_parity(12, d, jnp.arange(d, dtype=jnp.int32), d, seed=2)

    def test_tile_aligned_no_padding(self):
        # exercise the raw kernel entry point without the ops padding wrapper
        n_is, d, n_seg = TILE_I, 2 * TILE_D, NSEG_LANE
        seg = jnp.asarray(np.repeat(np.arange(n_seg), d // n_seg), jnp.int32)
        u, p, a, b = _inputs(3, n_is, d)
        ref = mrc.default_segment_logw(u, p, a, b, seg, n_seg)
        out = segment_logw_pallas(u, p[None], a[None], b[None], seg[None],
                                  n_seg=n_seg, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)


class TestEncodeSegmentsEndToEnd:
    """The pluggable hook reproduces the default route bit-for-bit at these
    fixed seeds: the two logW evaluations differ only by f32 grouping
    order, far below the Gumbel-argmax gaps at these sizes."""

    SEG = np.repeat(np.arange(5), [10, 2, 40, 30, 14])

    def _keys(self):
        k = jax.random.fold_in(KEY, 99)
        ks, kq, kp, ksel = jax.random.split(k, 4)
        q = clip01(jax.random.uniform(kq, (96,)))
        p = clip01(jax.random.uniform(kp, (96,)))
        return ks, ksel, q, p

    def test_encode_matches_default(self):
        ks, ksel, q, p = self._keys()
        seg = jnp.asarray(self.SEG, jnp.int32)
        r0 = mrc.encode_segments(ks, ksel, q, p, seg, n_is=32, n_seg=5)
        r1 = mrc.encode_segments(ks, ksel, q, p, seg, n_is=32, n_seg=5,
                                 seg_logw_fn=ops.segment_logw_fn())
        np.testing.assert_array_equal(np.asarray(r0.indices),
                                      np.asarray(r1.indices))
        np.testing.assert_array_equal(np.asarray(r0.sample),
                                      np.asarray(r1.sample))

    def test_transmit_matches_default(self):
        ks, ksel, q, p = self._keys()
        seg = jnp.asarray(self.SEG, jnp.int32)
        i0, e0 = mrc.transmit_segments(ks, ksel, q, p, seg, n_is=16, n_seg=5,
                                       n_samples=3)
        i1, e1 = mrc.transmit_segments(ks, ksel, q, p, seg, n_is=16, n_seg=5,
                                       n_samples=3,
                                       seg_logw_fn=ops.segment_logw_fn())
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


class TestBernoulliKLProfile:
    def test_matches_host_mean(self):
        kq, kp = jax.random.split(jax.random.fold_in(KEY, 5))
        q = jax.random.uniform(kq, (5, 700), minval=0.01, maxval=0.99)
        p = jax.random.uniform(kp, (5, 700), minval=0.01, maxval=0.99)
        ref = jnp.mean(jax.vmap(bern_kl)(q, p), axis=0)
        out = ops.bernoulli_kl_profile(q, p, interpret=True)
        assert out.shape == (700,)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-6)


class TestShapePreconditions:
    """Tile-alignment misuse raises ValueError (not a stripped assert)."""

    def test_segment_logw_pallas(self):
        u, p, a, b = _inputs(0, 8, 40)
        seg = jnp.zeros((40,), jnp.int32)
        with pytest.raises(ValueError, match="segment_logw_pallas"):
            segment_logw_pallas(u, p[None], a[None], b[None], seg[None],
                                n_seg=NSEG_LANE, interpret=True)

    def test_bernoulli_kl_pallas(self):
        from repro.kernels.bernoulli_kl import bernoulli_kl_pallas
        bad = jnp.full((2, 100), 0.5)
        with pytest.raises(ValueError, match="bernoulli_kl_pallas"):
            bernoulli_kl_pallas(bad, bad, interpret=True)

    def test_mrc_logw_pallas(self):
        from repro.kernels.mrc_weights import mrc_logw_pallas
        with pytest.raises(ValueError, match="mrc_logw_pallas"):
            mrc_logw_pallas(jnp.zeros((1, 100, 128)), jnp.zeros((1, 128)),
                            jnp.zeros((1, 128)), interpret=True)

    def test_rwkv_chunk_pallas(self):
        from repro.kernels.rwkv_chunk import rwkv_chunk_pallas
        t = jnp.zeros((1, 5, 128))
        with pytest.raises(ValueError, match="rwkv_chunk_pallas"):
            rwkv_chunk_pallas(t, t, t, t, jnp.zeros((1, 1, 128)),
                              interpret=True)

    def test_flash_attention_pallas(self):
        from repro.kernels.flash_attn import flash_attention_pallas
        q = jnp.zeros((1, 5, 128))
        kv = jnp.zeros((1, 128, 128))
        with pytest.raises(ValueError, match="flash_attention_pallas"):
            flash_attention_pallas(q, kv, kv, causal=True, window=0,
                                   scale=1.0, skv=128, interpret=True)
