"""Fused-vs-host engine parity: the device-resident ``lax.scan`` path must
reproduce the host round loop **bit-for-bit** -- identical histories
(accuracy floats, cumulative bits), meters, and final ``theta`` /
``theta_hat`` arrays, exact equality with no tolerances.

Covers every registry scheme with a static block plan (all four BiCompFL
variants, BiCompFL-CFL, the seven baselines incl. the CSER/LIEC flush
path), full and partial participation, both cohort RNGs, and non-unit eval
cadence.

Adaptive allocations run fused through *bucketed* plans (``lax.switch``
over precompiled block sets, KL profile computed on device), so the host
loop's exact per-round plan is the parity *oracle* rather than a bitwise
twin: accuracy must agree within tolerance and total bits must respect the
bucketing bound (conservative: never above the exact plan's budget plus the
allocation's declared ``bucket_overhead_bits``).  When the bucket set
contains the exact plan -- always true for AdaptiveAvg, whose buckets *are*
its pow2 plan space, and arranged via ``buckets=`` for the segment codec --
parity is again exact.
"""
import math

import jax
import numpy as np
import pytest

from repro.core.blocks import (AdaptiveAllocation, AdaptiveAvgAllocation,
                               FixedAllocation)
from repro.fl import registry
from repro.fl.data import make_synthetic, partition_iid
from repro.fl.engine import FLEngine
from repro.fl.nets import make_mlp
from repro.fl.tasks import make_cfl_task, make_mask_task

SCHEMES = registry.all_schemes(n=3, d=1472, n_is=16, block=64, reset_period=2)


@pytest.fixture(scope="module")
def mask_setup():
    k = jax.random.PRNGKey(3)
    train, test = make_synthetic(k, n_train=240, n_test=120, hw=6, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, 3, 80)
    net = make_mlp(in_dim=36, widths=(32,), signed_constant=True)
    task = make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                          local_epochs=1, batch_size=40)
    return task, shards


@pytest.fixture(scope="module")
def cfl_setup():
    k = jax.random.PRNGKey(4)
    train, test = make_synthetic(k, n_train=240, n_test=120, hw=6, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, 3, 80)
    net = make_mlp(in_dim=36, widths=(32,))
    task, theta0 = make_cfl_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                                 local_epochs=2, batch_size=40, local_lr=3e-3)
    assert int(theta0.shape[0]) == 1472  # keep SCHEMES' d in sync
    return task, theta0, shards


def _assert_identical(host, fused):
    assert len(host["history"]) == len(fused["history"])
    for hh, hf in zip(host["history"], fused["history"]):
        for key in hh:
            assert hf[key] == hh[key], (key, hh, hf)
    for key in host["meter"]:
        assert fused["meter"][key] == host["meter"][key], key
    np.testing.assert_array_equal(np.asarray(host["theta"]),
                                  np.asarray(fused["theta"]))
    np.testing.assert_array_equal(np.asarray(host["theta_hat"]),
                                  np.asarray(fused["theta_hat"]))
    np.testing.assert_array_equal(host["active_schedule"],
                                  fused["active_schedule"])
    assert fused["final_acc"] == host["final_acc"]
    assert fused["max_acc"] == host["max_acc"]


def _run_both(task, spec_factory, shards, theta0=None, *, rounds=3, seed=11,
              **kw):
    host = FLEngine(task, spec_factory()).run(
        shards, theta0, rounds=rounds, seed=seed, mode="host", **kw)
    fused = FLEngine(task, spec_factory()).run(
        shards, theta0, rounds=rounds, seed=seed, mode="fused", **kw)
    _assert_identical(host, fused)
    return host


@pytest.mark.parametrize("name,kind,factory", SCHEMES,
                         ids=[s[0] for s in SCHEMES])
def test_fused_matches_host(mask_setup, cfl_setup, name, kind, factory):
    if kind == "mask":
        task, shards = mask_setup
        _run_both(task, factory, shards)
    else:
        task, theta0, shards = cfl_setup
        # reset_period=2 inside 3 rounds exercises the lax.cond flush branch
        _run_both(task, factory, shards, theta0)


@pytest.mark.parametrize("cohort_rng", ["numpy", "jax"])
def test_fused_partial_participation(mask_setup, cohort_rng):
    task, shards = mask_setup
    factory = lambda: registry.bicompfl_spec(
        "PR", allocation=FixedAllocation(64), n_is=16, n_dl=3,
        participation=0.67)
    out = _run_both(task, factory, shards, rounds=3, cohort_rng=cohort_rng)
    assert out["active_schedule"].shape == (3, 2)  # 0.67 of 3 -> 2 active


def test_fused_eval_cadence(mask_setup):
    """lax.cond-gated eval: only scheduled rounds (plus the last) appear."""
    task, shards = mask_setup
    factory = lambda: registry.bicompfl_spec(
        "GR", allocation=FixedAllocation(64), n_is=16, n_dl=3)
    out = _run_both(task, factory, shards, rounds=3, eval_every=2)
    assert [h["round"] for h in out["history"]] == [2, 3]


class _ProbedAdaptive(AdaptiveAllocation):
    """Records each exact host plan's *requested* block count -- the value
    ``select_bucket`` floors onto the grid -- to build exact bucket sets.

    Exact fused-vs-host parity is only constructible when no duplicate
    binning edges collapse (the host gumbel capacity is the post-collapse
    count while a switch branch's capacity is static), so the probe
    asserts the premise loudly instead of letting a future fp change
    surface as an inscrutable bit mismatch."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.planned = []

    def plan(self, kl, d):
        out = super().plan(kl, d)
        if kl is not None:
            total = float(np.sum(kl)) + 1e-12
            target = self.target_ratio * math.log(self.n_is)
            requested = min(self._cap(d),
                            max(self.min_blocks, math.ceil(total / target)))
            assert requested == out[1], \
                "binning edges collapsed; exact-parity premise broken"
            self.planned.append(requested)
        return out


def _run_adaptive_pair(task, shards, make_alloc, *, rounds=3, seed=11,
                       variant="GR", **kw):
    """Host (exact plan) vs fused (bucketed plan) for an adaptive scheme."""
    n = int(shards.x.shape[0])
    host = FLEngine(task, registry.bicompfl_spec(
        variant, allocation=make_alloc(), n_is=16, n_dl=n, **kw)).run(
        shards, rounds=rounds, seed=seed, mode="host")
    fused = FLEngine(task, registry.bicompfl_spec(
        variant, allocation=make_alloc(), n_is=16, n_dl=n, **kw)).run(
        shards, rounds=rounds, seed=seed, mode="fused")
    return host, fused


def test_adaptive_fused_supported_no_fallback(mask_setup):
    """The PR 2 host auto-fallback is gone: adaptive allocations are fused-
    eligible, mode="fused" runs them, and mode="auto" picks the fused path."""
    task, shards = mask_setup
    spec = registry.bicompfl_spec("GR", allocation=AdaptiveAllocation(n_is=16),
                                  n_is=16, n_dl=3)
    engine = FLEngine(task, spec)
    assert engine.fused_supported()
    auto = engine.run(shards, rounds=2, seed=11, mode="auto")
    assert auto["mode"] == "fused"
    fused = engine.run(shards, rounds=2, seed=11, mode="fused")
    _assert_identical(fused, auto)


def test_non_functional_channel_still_host_only(mask_setup):
    """Revised eligibility: only non-functional channels force the host loop
    (plus allocations exposing neither a static plan nor the bucket API)."""
    task, shards = mask_setup

    class LegacyOnlyDownlink:  # object shell without the functional core
        broadcast_shareable = True

        def distribute(self, ctx, update, theta, theta_hat):
            raise NotImplementedError

    spec = registry.bicompfl_spec("GR", allocation=FixedAllocation(64),
                                  n_is=16, n_dl=3)
    spec.downlink = LegacyOnlyDownlink()
    assert not FLEngine(task, spec).fused_supported()

    class NoBucketAdaptive:  # data-dependent plan without the bucket API
        static_plan = False
        needs_kl = True

        def plan(self, kl, d):
            return 64, -(-d // 64), None, 0.0

    spec2 = registry.bicompfl_spec("GR", allocation=FixedAllocation(64),
                                   n_is=16, n_dl=3)
    spec2.allocation = NoBucketAdaptive()
    engine2 = FLEngine(task, spec2)
    assert not engine2.fused_supported()
    with pytest.raises(ValueError):
        engine2.run(shards, rounds=1, seed=1, mode="fused")


def test_fused_adaptive_avg_exact_parity(mask_setup):
    """AdaptiveAvg's bucket set IS its pow2 plan space, so the fused bucketed
    run reproduces the host exact-plan run bit-for-bit (bits included)."""
    task, shards = mask_setup
    host, fused = _run_adaptive_pair(
        task, shards,
        lambda: AdaptiveAvgAllocation(n_is=16, min_block=32, max_block=512))
    assert fused["mode"] == "fused" and host["mode"] == "host"
    _assert_identical(host, fused)


def test_fused_adaptive_exact_bucket_contains_plan(mask_setup):
    """Segment codec: when the bucket set contains every exact per-round
    block count, the fused run is bit-identical to the host oracle."""
    task, shards = mask_setup
    probe = _ProbedAdaptive(n_is=16, target_ratio=0.02)
    host = FLEngine(task, registry.bicompfl_spec(
        "GR", allocation=probe, n_is=16, n_dl=3)).run(
        shards, rounds=3, seed=11, mode="host")
    assert len(set(probe.planned)) > 1  # the plan really moves across rounds
    fused = FLEngine(task, registry.bicompfl_spec(
        "GR", allocation=AdaptiveAllocation(
            n_is=16, target_ratio=0.02, buckets=tuple(probe.planned)),
        n_is=16, n_dl=3)).run(shards, rounds=3, seed=11, mode="fused")
    _assert_identical(host, fused)


def test_fused_adaptive_bucketing_bound(mask_setup):
    """Default (geometric) buckets: accuracy stays within tolerance of the
    exact-plan host oracle.  Bits: the conservativeness guarantee is
    per-round-for-the-same-KL-profile (tests/test_allocation.py pins it),
    so only round 1 -- where both trajectories share the initial state --
    gets the strict inequality; after that the trajectories drift and the
    whole run is held to a band, exactly like the benchmark oracle."""
    task, shards = mask_setup
    make_alloc = lambda: AdaptiveAllocation(n_is=16, target_ratio=0.02)
    host, fused = _run_adaptive_pair(task, shards, make_alloc)
    accs_h = np.array([h["acc"] for h in host["history"]])
    accs_f = np.array([h["acc"] for h in fused["history"]])
    np.testing.assert_allclose(accs_f, accs_h, atol=0.2)
    bound = make_alloc().bucket_overhead_bits  # declared, per round
    assert fused["history"][0]["cum_bits"] <= \
        host["history"][0]["cum_bits"] + bound  # round 1: same KL profile
    ratio = fused["meter"]["total_bits"] / host["meter"]["total_bits"]
    assert 0.4 <= ratio <= 2.0


@pytest.mark.parametrize("cohort_rng", ["numpy", "jax"])
def test_fused_adaptive_partial_participation(mask_setup, cohort_rng):
    """PR + segment codec under partial participation: the KL profile and
    the bucketed plan are derived from the active cohort only, on device.
    With the probed exact bucket set the fused run must again be
    bit-identical to the host oracle -- under both cohort RNGs."""
    task, shards = mask_setup
    probe = _ProbedAdaptive(n_is=16, target_ratio=0.02)
    host = FLEngine(task, registry.bicompfl_spec(
        "PR", allocation=probe, n_is=16, n_dl=3,
        participation=0.67)).run(
        shards, rounds=3, seed=11, mode="host", cohort_rng=cohort_rng)
    fused = FLEngine(task, registry.bicompfl_spec(
        "PR", allocation=AdaptiveAllocation(
            n_is=16, target_ratio=0.02, buckets=tuple(probe.planned)),
        n_is=16, n_dl=3, participation=0.67)).run(
        shards, rounds=3, seed=11, mode="fused", cohort_rng=cohort_rng)
    assert fused["mode"] == "fused"
    assert fused["active_schedule"].shape == (3, 2)  # 0.67 of 3 -> 2 active
    _assert_identical(host, fused)


def test_fixed_allocation_auto_uses_fused(mask_setup):
    task, shards = mask_setup
    engine = FLEngine(task, registry.bicompfl_spec(
        "GR", allocation=FixedAllocation(64), n_is=16, n_dl=3))
    assert engine.fused_supported()
