"""Pallas TPU kernels for BiCompFL hot-spots (validated via interpret=True)."""
from . import ops, ref  # noqa: F401
