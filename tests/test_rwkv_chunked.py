"""Chunked RWKV6 closed form vs the per-token reference recurrence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import rwkv6, transformer as T

KEY = jax.random.PRNGKey(11)


def _streams(b=2, s=48, h=3, dh=8, key=KEY):
    ks = jax.random.split(key, 4)
    rf = jax.random.normal(ks[0], (b, s, h, dh))
    kf = jax.random.normal(ks[1], (b, s, h, dh))
    vf = jax.random.normal(ks[2], (b, s, h, dh))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, dh)) - 2.0)
    u = jax.random.normal(jax.random.fold_in(key, 5), (h, dh)) * 0.1
    s0 = jax.random.normal(jax.random.fold_in(key, 6), (b, h, dh, dh)) * 0.1
    return rf, kf, vf, logw, u, s0


@pytest.mark.parametrize("chunk", [4, 16, 48, 64])
def test_chunked_matches_sequential(chunk):
    rf, kf, vf, logw, u, s0 = _streams()
    o_ref, s_ref = rwkv6._time_mix_sequential(rf, kf, vf, logw, u, s0)
    o_chk, s_chk = rwkv6._time_mix_chunked(rf, kf, vf, logw, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_chunked_nondivisible_length():
    rf, kf, vf, logw, u, s0 = _streams(s=37)
    o_ref, s_ref = rwkv6._time_mix_sequential(rf, kf, vf, logw, u, s0)
    o_chk, s_chk = rwkv6._time_mix_chunked(rf, kf, vf, logw, u, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_strong_decay_numerically_safe():
    rf, kf, vf, logw, u, s0 = _streams(s=32)
    logw = jnp.full_like(logw, -15.0)   # near-total forgetting
    o, s_fin = rwkv6._time_mix_chunked(rf, kf, vf, logw, u, s0, chunk=8)
    assert bool(jnp.all(jnp.isfinite(o))) and bool(jnp.all(jnp.isfinite(s_fin)))


def test_full_model_chunked_matches_forward():
    """End-to-end: rwkv6 reduced model, chunked vs sequential logits."""
    cfg = C.get("rwkv6-1.6b").reduced()
    cfg_chunked = dataclasses.replace(cfg, scan_chunk=8)
    model = T.build(cfg)
    model_c = T.build(cfg_chunked)
    params, _ = T.init_params(model, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    l_ref, _ = T.forward(model, params, {"tokens": toks}, kv_chunk=8)
    l_chk, _ = T.forward(model_c, params, {"tokens": toks}, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(l_chk, np.float32),
                               np.asarray(l_ref, np.float32),
                               rtol=5e-3, atol=5e-3)
