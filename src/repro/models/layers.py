"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention, FFN.

Everything is functional: ``init_*`` returns a params dict (+ a matching
PartitionSpec dict), ``*_apply`` consumes it.  Activations are annotated with
``sharding.constraint`` so pjit/GSPMD propagates the intended layout.

Attention is implemented in a chunked (flash-style, lazy-softmax) form: the
KV sequence is scanned in blocks with a running (max, denominator)
accumulator, so the full (S x S) score matrix is never materialised -- the
requirement for the 32k prefill shapes to fit HBM at scale.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import sharding
from .config import ArchConfig

# Negative-infinity substitute that is safe in bf16 softmax arithmetic.
NEG_INF = -1e9


def dtype_of(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies (head_dim/2,) float32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, Dh); positions: broadcastable (..., S)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                                   # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv          # (..., S, Dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (..., S, 1, Dh/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (..., S, H, Dh); positions3: (..., S, 3) -- (t, h, w) position ids.
    The Dh/2 frequency slots are partitioned into three contiguous sections
    (temporal / height / width); each section rotates by its own position id.
    """
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(dh, theta)                                   # (Dh/2,)
    # section id per frequency slot -> pick the matching position stream
    sect = jnp.concatenate([
        jnp.full((sections[0],), 0), jnp.full((sections[1],), 1),
        jnp.full((sections[2],), 2)]).astype(jnp.int32)           # (Dh/2,)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sect, positions3.shape[:-1] + (half,)), axis=-1)  # (..., S, Dh/2)
    ang = pos * inv
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def rotate(cfg: ArchConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.rope_kind == "none":
        return x
    if cfg.rope_kind == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


# ---------------------------------------------------------------------------
# Attention params
# ---------------------------------------------------------------------------


def init_attn(key: jax.Array, cfg: ArchConfig):
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    std = d ** -0.5
    params = {
        "wq": (jax.random.normal(ks[0], (d, h * dh)) * std).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, hk * dh)) * std).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, hk * dh)) * std).astype(dt),
        "wo": (jax.random.normal(ks[3], (h * dh, d)) * std).astype(dt),
    }
    specs = {
        "wq": P(None, "model"), "wk": P(None, "model"),
        "wv": P(None, "model"), "wo": P("model", None),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.zeros((dh,), dt)
        params["k_norm"] = jnp.zeros((dh,), dt)
        specs["q_norm"] = P(None)
        specs["k_norm"] = P(None)
    return params, specs


def kv_head_spec(cfg: ArchConfig, model_size: int, *, for_cache: bool = False) -> P:
    """Spec for a (..., Hkv, Dh) pair of trailing axes.

    GQA kv-head counts (8) are often smaller than the model axis (16).  For
    the *decode cache* (memory-bound) we shard head_dim instead; for
    training/prefill activations we replicate the kv heads -- sharding the
    score-contraction dim forces per-chunk psums and involuntary remats.
    """
    if cfg.n_kv_heads % max(model_size, 1) == 0:
        return P("model", None)
    if for_cache and cfg.head_dim % max(model_size, 1) == 0:
        return P(None, "model")
    return P(None, None)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention
# ---------------------------------------------------------------------------


def _chunk_attn_scan(q, k, v, *, causal: bool, window: int, q_offset: int,
                     kv_chunk: int, scale: float):
    """Lazy-softmax attention, scanning KV in chunks.

    q: (B, Sq, H, Dh);  k, v: (B, Skv, Hkv, Dh).  Returns (B, Sq, H, Dh).
    ``q_offset``: absolute position of q[0] (for decode: Skv-1 typically).
    ``window`` > 0 restricts to a sliding window (positions within `window`).
    """
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    q32 = q.astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(sq)

    def body(carry, c):
        m, l, acc = carry                     # (B,H,Sq), (B,H,Sq), (B,H,Sq,Dh)
        kc = jax.lax.dynamic_slice_in_dim(k, c * kv_chunk, kv_chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, c * kv_chunk, kv_chunk, axis=1)
        kc = jnp.repeat(kc.astype(jnp.float32), rep, axis=2)      # (B,C,H,Dh)
        vc = jnp.repeat(vc.astype(jnp.float32), rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, kc)                # (B,H,Sq,C)
        kpos = c * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.broadcast_to(kpos[None, :] < skv, (sq, kv_chunk))  # non-pad
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        # NOTE: casting p to bf16 for the PV contraction was measured to
        # *increase* HBM traffic (the convert materialises the score tensor
        # an extra time; §Perf qwen3 iteration 3, refuted).  The real fix is
        # the Pallas flash kernel (kernels/flash_attn.py) where scores never
        # leave VMEM -- XLA cannot express that fusion.
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vc)
        return (m_new, l_new, acc_new), ()

    init = (jnp.full((b, h, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, dh), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]                  # (B,H,Sq,Dh)
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)      # (B,Sq,H,Dh)


def attention(cfg: ArchConfig, params, x: jax.Array, positions: jax.Array,
              *, kv_chunk: int = 1024):
    """Multi-head GQA self attention (training / prefill).

    x: (B, S, d); positions: (B, S) (or (B, S, 3) for M-RoPE).
    """
    b, s, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    model_sz = sharding.axis_size("model")

    # Explicit q/k/v constraints: dropping them was measured to flip GSPMD
    # into a head<->sequence all-to-all strategy that raised total
    # collective bytes 7.1e11 -> 1.2e12 per device (§Perf qwen3 iter 1,
    # refuted hypothesis) -- keep the annotated layout.
    hspec = kv_head_spec(cfg, model_sz)
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, hk, dh)
    v = (x @ params["wv"]).reshape(b, s, hk, dh)
    q = sharding.constraint(q, P(sharding.batch_axes(), None, "model", None))
    k = sharding.constraint(k, P(sharding.batch_axes(), None, *hspec))
    v = sharding.constraint(v, P(sharding.batch_axes(), None, *hspec))

    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])

    q = rotate(cfg, q, positions)
    k = rotate(cfg, k, positions)
    out = _chunk_attn_scan(
        q, k, v, causal=cfg.causal, window=cfg.sliding_window,
        q_offset=0, kv_chunk=min(kv_chunk, s), scale=dh ** -0.5)

    out = out.reshape(b, s, h * dh)
    out = out @ params["wo"]
    return sharding.constraint(out, P(sharding.batch_axes(), None, None))


# Symmetric int8 KV quantization is applied per group of KV_QUANT_GROUP
# channels (not per full head vector): one outlier channel then only costs
# its own group's resolution.  Scales are stored f16 -- the 2-byte scale per
# 16 int8 payload bytes keeps the cache at 0.5625x of the bf16 footprint.
KV_QUANT_GROUP = 16


def _kv_groups(dh: int) -> int:
    return KV_QUANT_GROUP if dh % KV_QUANT_GROUP == 0 else dh


def quantize_kv(t: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Group-wise symmetric int8 quantization of (B,S,Hkv,Dh).

    Returns (int8 payload (B,S,Hkv,Dh), f16 scales (B,S,Hkv,Dh/G))."""
    g = _kv_groups(t.shape[-1])
    tg = t.astype(jnp.float32).reshape(t.shape[:-1] + (-1, g))
    scale = jnp.max(jnp.abs(tg), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(tg / scale[..., None]), -127, 127)
    return q.reshape(t.shape).astype(jnp.int8), scale.astype(jnp.float16)


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    g = _kv_groups(q.shape[-1])
    qg = q.astype(jnp.float32).reshape(q.shape[:-1] + (-1, g))
    out = qg * scale.astype(jnp.float32)[..., None]
    return out.reshape(q.shape)


def decode_attention(cfg: ArchConfig, params, x: jax.Array, pos: jax.Array,
                     kv_cache, *, kv_chunk: int = 2048):
    """Single-token decode attention with an explicit validity mask.

    x: (B, 1, d); pos: scalar int (current absolute position, == valid len).
    kv_cache: (k, v) each (B, S_max, Hkv, Dh) -- or, with
    cfg.kv_cache_quant, (k_i8, v_i8, k_scale, v_scale) with int8 payloads
    and (B, S_max, Hkv, Dh/KV_QUANT_GROUP) f16 group scales (0.5625x of
    the bf16 cache footprint).
    Positions >= pos are masked.  For sliding-window configs the cache may
    hold only the window (S_max == window), written at ``pos % S_max``
    (ring buffer).
    """
    b, s, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    model_sz = sharding.axis_size("model")
    hspec = kv_head_spec(cfg, model_sz, for_cache=True)
    quant = cfg.kv_cache_quant
    if quant:
        ck, cv, ck_s, cv_s = kv_cache
    else:
        ck, cv = kv_cache
    s_max = ck.shape[1]
    ring = cfg.sliding_window > 0 and s_max < 10**9

    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, hk, dh)
    v = (x @ params["wv"]).reshape(b, s, hk, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    posv = jnp.full((b, 1), pos)
    q = rotate(cfg, q, posv) if cfg.rope_kind != "mrope" else rotate(
        cfg, q, jnp.broadcast_to(posv[..., None], (b, 1, 3)))
    k = rotate(cfg, k, posv) if cfg.rope_kind != "mrope" else rotate(
        cfg, k, jnp.broadcast_to(posv[..., None], (b, 1, 3)))

    slot = jnp.mod(pos, s_max) if ring else pos
    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, kq, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, vq, slot, axis=1)
        ck_s = jax.lax.dynamic_update_slice_in_dim(ck_s, ks, slot, axis=1)
        cv_s = jax.lax.dynamic_update_slice_in_dim(cv_s, vs, slot, axis=1)
        kk_full = dequantize_kv(ck, ck_s)
        vv_full = dequantize_kv(cv, cv_s)
        # The current token's K/V are still at hand in full precision; only
        # *past* positions pay the int8 round trip.
        kk_full = jax.lax.dynamic_update_slice_in_dim(
            kk_full, k.astype(jnp.float32), slot, axis=1)
        vv_full = jax.lax.dynamic_update_slice_in_dim(
            vv_full, v.astype(jnp.float32), slot, axis=1)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot, axis=1)
        kk_full = ck.astype(jnp.float32)
        vv_full = cv.astype(jnp.float32)
    ck = sharding.constraint(ck, P(sharding.batch_axes(), None, *hspec))
    cv = sharding.constraint(cv, P(sharding.batch_axes(), None, *hspec))

    rep = h // hk
    kk = jnp.repeat(kk_full, rep, axis=2)
    vv = jnp.repeat(vv_full, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * dh ** -0.5, kk)
    kpos = jnp.arange(s_max)
    valid = kpos[None, :] <= jnp.minimum(pos, s_max - 1) if not ring else \
        (kpos[None, :] >= 0)  # ring: every slot holds a token once pos >= s_max
    if ring:
        # slots beyond the number of tokens written so far are invalid
        valid = kpos[None, :] < jnp.minimum(pos + 1, s_max)
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv).astype(x.dtype)
    out = out.reshape(b, s, h * dh) @ params["wo"]
    new_cache = (ck, cv, ck_s, cv_s) if quant else (ck, cv)
    return sharding.constraint(out, P(sharding.batch_axes(), None, None)), new_cache


# ---------------------------------------------------------------------------
# Dense (SwiGLU) FFN
# ---------------------------------------------------------------------------


def init_ffn(key: jax.Array, cfg: ArchConfig, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    params = {
        "w_gate": (jax.random.normal(ks[0], (d, ff)) * d ** -0.5).astype(dt),
        "w_up": (jax.random.normal(ks[1], (d, ff)) * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(ks[2], (ff, d)) * ff ** -0.5).astype(dt),
    }
    specs = {"w_gate": P(None, "model"), "w_up": P(None, "model"),
             "w_down": P("model", None)}
    return params, specs


def ffn(params, x: jax.Array) -> jax.Array:
    hidden = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    hidden = sharding.constraint(hidden, P(sharding.batch_axes(), None, "model"))
    out = hidden @ params["w_down"]
    return sharding.constraint(out, P(sharding.batch_axes(), None, None))
