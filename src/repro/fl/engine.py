"""The one FL round loop: local-train -> uplink -> aggregate -> downlink.

Every training loop in the repo -- the four BiCompFL variants, BiCompFL-CFL,
and all seven non-stochastic baselines -- is an :class:`EngineSpec`
(uplink channel, downlink channel, aggregator, plus block allocation and
participation policy) executed by :class:`FLEngine`.  The engine owns the
things every scheme shares and that used to be copy-pasted per loop:

* shared-randomness key schedule (round key, per-client training keys),
* partial participation (cohort sampling; inactive clients are *not*
  trained),
* the block-allocation control plane,
* periodic error-feedback synchronisation (CSER / LIEC style ``flush``),
* BitMeter accounting and evaluation history.

Two execution paths produce bit-for-bit identical results
(tests/test_fused_parity.py):

* **host** -- a Python round loop dispatching jitted sub-computations; the
  only path for schemes whose block allocation is data-dependent
  (AdaptiveAllocation / AdaptiveAvgAllocation recompute the plan from the
  round's KL profile, which is host-side control plane).
* **fused** -- the entire multi-round run is ONE ``jax.lax.scan`` over
  rounds: channel state (error-feedback memories) is an explicit carry
  pytree threaded through the pure ``step_up`` / ``step_down`` functions,
  evaluation folds in via ``lax.cond`` on the eval schedule, and the EF
  sync flush is a ``lax.cond`` branch.  Per-round *bits* are
  data-independent (static shapes x static plan), so communication is
  booked host-side after the scan with zero device round-trips -- the only
  device->host transfer of a whole run is the stacked accuracy vector.

Cohort sampling is precomputed as a (rounds, n_active) schedule.
``cohort_rng="numpy"`` reproduces the seed's ``default_rng(seed+17)`` draws
(bit-compatible with the legacy loops); ``cohort_rng="jax"`` derives the
cohort from the round key (``fold_in(kt, TAG_COHORT)``), making the whole
run a pure function of ``seed`` with no host RNG.

The engine reproduces the seed loops bit-for-bit at full participation
(tests/test_engine_parity.py); see DESIGN.md for the API contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrc
from repro.core.bernoulli import bern_kl, clip01
from repro.core.bitmeter import BitMeter
from .channels import (BlockPlan, RoundContext, ServerUpdate, TAG_COHORT,
                       TAG_TRAIN, pin)
from .data import Dataset


# ---------------------------------------------------------------------------
# Aggregators: uplink output -> proposed server update.
# ---------------------------------------------------------------------------


class MeanModelAggregator:
    """BiCompFL: the mean of the conveyed posterior samples *is* the model."""

    def __call__(self, ctx, theta, up_out) -> ServerUpdate:
        return ServerUpdate(theta=jnp.mean(up_out, axis=0))


@dataclass
class MeanDeltaAggregator:
    """Conventional FL: average the (compressed) deltas, step the server."""

    server_lr: float = 1.0

    def __call__(self, ctx, theta, up_out) -> ServerUpdate:
        # The mean feeds the server step; pinned so the fused engine cannot
        # FMA-contract mean's scale into the subtraction (cf. channels.pin).
        g = pin(getattr(ctx, "pin_token", None), jnp.mean(up_out, axis=0))
        return ServerUpdate(theta=theta - self.server_lr * g, delta=g,
                            lr=self.server_lr)


# ---------------------------------------------------------------------------
# Engine.
# ---------------------------------------------------------------------------


@dataclass
class EngineSpec:
    """A complete FL scheme: who compresses what, in which direction."""

    uplink: Any
    downlink: Any
    aggregator: Any
    allocation: Any = None       # block-allocation strategy (MRC schemes)
    participation: float = 1.0   # fraction of clients active per round
    sync_period: int = 0         # 0 = never; else flush EF memories every k
    name: str = ""


class FLEngine:
    """Runs an :class:`EngineSpec` against a task and sharded dataset."""

    def __init__(self, task, spec: EngineSpec):
        self.task = task
        self.spec = spec

    # -- fused-path eligibility -------------------------------------------

    def fused_supported(self) -> bool:
        """True when the whole run can compile to one scanned XLA program.

        Requires (a) a round-independent block plan -- ``allocation`` is
        None or declares ``static_plan`` (adaptive allocations recompute
        the plan from each round's KL profile on the host), and (b) both
        channels implementing the functional step protocol.
        """
        spec = self.spec
        if spec.allocation is not None and \
                not getattr(spec.allocation, "static_plan", False):
            return False
        up_ok = all(hasattr(spec.uplink, a)
                    for a in ("step_up", "init_up_state", "flush_step"))
        dn_ok = all(hasattr(spec.downlink, a)
                    for a in ("step_down", "init_down_state", "flush_step"))
        return up_ok and dn_ok

    # -- cohort schedule ---------------------------------------------------

    @staticmethod
    def cohort_schedule(rounds: int, n: int, n_active: int, seed: int,
                        cohort_rng: str = "numpy") -> np.ndarray:
        """Precompute the (rounds, n_active) active-cohort table.

        ``numpy`` consumes ``default_rng(seed+17)`` exactly as the seed
        loops did (one sorted no-replacement draw per round, in round
        order), so precomputing changes nothing.  ``jax`` derives each
        round's cohort from the shared round key instead.
        """
        if cohort_rng not in ("numpy", "jax"):
            raise ValueError(cohort_rng)
        if n_active >= n:
            return np.tile(np.arange(n, dtype=np.int64), (rounds, 1))
        if cohort_rng == "numpy":
            rng = np.random.default_rng(seed + 17)
            return np.stack([np.sort(rng.choice(n, size=n_active, replace=False))
                             for _ in range(rounds)])
        base = jax.random.PRNGKey(seed)

        def one(t):
            kc = jax.random.fold_in(mrc.round_key(base, t), TAG_COHORT)
            return jnp.sort(jax.random.choice(
                kc, n, (n_active,), replace=False))

        sched = jax.vmap(one)(jnp.arange(rounds))
        return np.asarray(sched, dtype=np.int64)

    # -- entry point -------------------------------------------------------

    def run(self, shards: Dataset, theta0: Optional[jax.Array] = None, *,
            rounds: int, seed: int = 0, eval_every: int = 1,
            mode: str = "auto", cohort_rng: str = "numpy") -> Dict[str, Any]:
        """Run the scheme.  ``mode``: "auto" (fused when eligible), "host",
        or "fused" (raises for schemes needing the host control plane)."""
        task, spec = self.task, self.spec
        # Stateful channels (error-feedback memories) must start fresh: a
        # spec may be run more than once.
        for chan in (spec.uplink, spec.downlink):
            reset = getattr(chan, "reset", None)
            if reset is not None:
                reset()
        n = int(shards.x.shape[0])
        theta = task.init_theta() if theta0 is None else theta0
        d = int(theta.shape[0])
        theta_hat = jnp.tile(theta[None], (n, 1))
        meter = BitMeter(
            n_clients=n, d=d,
            broadcast_downlink_shareable=getattr(
                spec.downlink, "broadcast_shareable", True))
        n_active = max(1, int(round(spec.participation * n)))
        schedule = self.cohort_schedule(rounds, n, n_active, seed, cohort_rng)

        if mode not in ("auto", "host", "fused"):
            raise ValueError(mode)
        fused_ok = self.fused_supported()
        if mode == "fused" and not fused_ok:
            raise ValueError(
                f"spec {spec.name!r} needs the host control plane "
                "(data-dependent block allocation or non-functional channels)")
        runner = self._run_fused if (fused_ok and mode != "host") \
            else self._run_host
        out = runner(shards, theta, theta_hat, meter, rounds=rounds,
                     seed=seed, eval_every=eval_every, schedule=schedule)
        out["active_schedule"] = schedule
        return out

    # -- host loop ---------------------------------------------------------

    def _run_host(self, shards, theta, theta_hat, meter, *, rounds, seed,
                  eval_every, schedule) -> Dict[str, Any]:
        task, spec = self.task, self.spec
        n, d = meter.n_clients, meter.d
        n_active = schedule.shape[1]
        base = jax.random.PRNGKey(seed)
        history: List[Dict[str, float]] = []

        for t in range(rounds):
            kt = mrc.round_key(base, t)
            active = schedule[t]

            # ---- local training: only the active cohort ------------------
            train_keys = jax.random.split(jax.random.fold_in(kt, TAG_TRAIN), n)
            if n_active < n:
                priors = theta_hat[active]
                xs, ys, keys = (shards.x[active], shards.y[active],
                                train_keys[active])
            else:  # full participation: no device-side gather/copy needed
                priors, xs, ys, keys = theta_hat, shards.x, shards.y, train_keys
            payload = jax.vmap(task.local_train)(priors, xs, ys, keys)

            # ---- block allocation (host-side control plane) --------------
            plan = None
            if spec.allocation is not None:
                kl = None
                if getattr(spec.allocation, "needs_kl", True):
                    kl = np.asarray(jnp.mean(jax.vmap(bern_kl)(
                        payload, clip01(priors)), axis=0))
                size, n_blocks, seg_ids, overhead = spec.allocation.plan(kl, d)
                plan = BlockPlan(size=size, n_blocks=n_blocks,
                                 seg_ids=seg_ids, overhead_bits=overhead)

            ctx = RoundContext(t=t, key=kt, n_clients=n, d=d, active=active,
                               plan=plan)

            # ---- uplink -> aggregate -> downlink -------------------------
            up_out, ul_bits = spec.uplink.transmit(ctx, payload, priors)
            update = spec.aggregator(ctx, theta, up_out)
            theta, theta_hat, dl_bits = spec.downlink.distribute(
                ctx, update, theta, theta_hat)

            # ---- periodic EF synchronisation (CSER / LIEC) ---------------
            if spec.sync_period and (t + 1) % spec.sync_period == 0:
                r_up, b_up = spec.uplink.flush(n, d)
                r_dn, b_dn = spec.downlink.flush(n, d)
                # flush at the aggregator's step size (update.lr), so a
                # hand-built spec cannot desync the reset from the rounds
                theta = theta - update.lr * (r_up + r_dn)
                theta_hat = jnp.tile(theta[None], (n, 1))
                ul_bits += b_up
                dl_bits += b_dn

            overhead_bits = plan.overhead_bits * n if plan is not None else 0.0
            meter.add_round(ul_bits, dl_bits, overhead_bits=overhead_bits)

            if (t + 1) % eval_every == 0 or t == rounds - 1:
                acc = task.evaluate(theta)
                history.append({"round": t + 1, "acc": float(acc),
                                "cum_bits": meter.total_bits,
                                "bpp_so_far": meter.total_bpp})

        return self._result(history, meter, theta, theta_hat)

    # -- fused loop: the whole run is one lax.scan over rounds -------------

    def _run_fused(self, shards, theta, theta_hat, meter, *, rounds, seed,
                   eval_every, schedule) -> Dict[str, Any]:
        task, spec = self.task, self.spec
        n, d = meter.n_clients, meter.d
        n_active = schedule.shape[1]
        full = n_active == n
        base = jax.random.PRNGKey(seed)

        plan = None
        if spec.allocation is not None:  # static: plan once for all rounds
            size, n_blocks, seg_ids, overhead = spec.allocation.plan(None, d)
            plan = BlockPlan(size=size, n_blocks=n_blocks, seg_ids=seg_ids,
                             overhead_bits=overhead)

        eval_mask = np.zeros(rounds, bool)
        eval_mask[eval_every - 1::eval_every] = True
        if rounds:
            eval_mask[-1] = True
        flush_mask = np.zeros(rounds, bool)
        if spec.sync_period:
            flush_mask[spec.sync_period - 1::spec.sync_period] = True

        # Bits are data-independent, so the single trace of the scan body
        # records the per-round (and per-flush) totals as plain floats.
        booked: Dict[str, Any] = {}

        # The host loop *materialises* each stage's output between separate
        # dispatches; inside one fused graph XLA instead fuses values into
        # their consumers, where LLVM FMA-contracts mul->sub chains into a
        # single rounding and breaks bit-parity.  Every cross-stage value is
        # therefore pinned through ``channels.pin`` (an integer-space
        # round-trip on a traced zero); the speedup comes from removing
        # per-round dispatch, not from cross-stage fusion.

        def body(carry, xs):
            theta, theta_hat, up_s, dn_s = carry
            kt = mrc.round_key(base, xs["t"])
            active = xs["active"]
            pp = xs["pin"]  # traced int32 zero: the rounding pin token

            train_keys = jax.random.split(jax.random.fold_in(kt, TAG_TRAIN), n)
            if full:
                priors, bx, by, keys = theta_hat, shards.x, shards.y, train_keys
            else:
                priors = theta_hat[active]
                bx, by, keys = shards.x[active], shards.y[active], \
                    train_keys[active]
            payload = pin(pp, jax.vmap(task.local_train)(priors, bx, by, keys))

            ctx = RoundContext(t=xs["t"], key=kt, n_clients=n, d=d,
                               active=active, plan=plan, pin_token=pp)
            up_out, ul_bits, up_s = spec.uplink.step_up(
                ctx, up_s, payload, priors)
            up_out, up_s = pin(pp, (up_out, up_s))
            update = spec.aggregator(ctx, theta, up_out)
            update = ServerUpdate(theta=pin(pp, update.theta),
                                  delta=pin(pp, update.delta)
                                  if update.delta is not None else None,
                                  lr=update.lr)
            res, dn_s = spec.downlink.step_down(
                ctx, dn_s, update, theta, theta_hat)
            theta, theta_hat, dn_s = pin(pp, (res.theta, res.theta_hat, dn_s))
            booked["round"] = (ul_bits, res.bits)

            if spec.sync_period:
                def do_flush(op):
                    th, thh, us, ds = op
                    r_up, b_up, us = spec.uplink.flush_step(us, n, d)
                    r_dn, b_dn, ds = spec.downlink.flush_step(ds, n, d)
                    booked["flush"] = (b_up, b_dn)
                    r_up, r_dn = pin(pp, (r_up, r_dn))  # residual means
                    th = th - update.lr * (r_up + r_dn)
                    return pin(pp, (th, jnp.tile(th[None], (n, 1)), us, ds))

                theta, theta_hat, up_s, dn_s = jax.lax.cond(
                    xs["flush"], do_flush, lambda op: op,
                    (theta, theta_hat, up_s, dn_s))

            acc = jax.lax.cond(
                xs["eval"],
                lambda th: jnp.asarray(task.evaluate(th), jnp.float32),
                lambda th: jnp.full((), jnp.nan, jnp.float32), theta)
            return (theta, theta_hat, up_s, dn_s), acc

        carry0 = (theta, theta_hat,
                  spec.uplink.init_up_state(n, d),
                  spec.downlink.init_down_state(n, d))
        xs = {"t": jnp.arange(rounds, dtype=jnp.int32),
              "active": jnp.asarray(schedule),
              "eval": jnp.asarray(eval_mask),
              "flush": jnp.asarray(flush_mask),
              "pin": jnp.zeros(rounds, jnp.int32)}
        (theta, theta_hat, _, _), accs = jax.lax.scan(body, carry0, xs)

        # ---- host-side communication booking (no device involvement) -----
        ul_base, dl_base = booked["round"]
        fl_up, fl_dn = booked.get("flush", (0.0, 0.0))
        snaps = meter.book_run(
            [ul_base + (fl_up if flush_mask[t] else 0.0)
             for t in range(rounds)],
            [dl_base + (fl_dn if flush_mask[t] else 0.0)
             for t in range(rounds)],
            overhead_bits=plan.overhead_bits * n if plan is not None else 0.0,
            snapshot_mask=eval_mask)
        accs = np.asarray(accs)  # the run's single device->host transfer
        history: List[Dict[str, float]] = [
            {"round": int(t) + 1, "acc": float(accs[t]),
             "cum_bits": cum_bits, "bpp_so_far": bpp}
            for t, (cum_bits, bpp) in zip(np.nonzero(eval_mask)[0], snaps)]
        return self._result(history, meter, theta, theta_hat)

    @staticmethod
    def _result(history, meter, theta, theta_hat) -> Dict[str, Any]:
        return {"history": history, "meter": meter.summary(),
                "theta": theta, "theta_hat": theta_hat,
                "final_acc": history[-1]["acc"] if history else float("nan"),
                "max_acc": max(h["acc"] for h in history)
                if history else float("nan")}


def run_spec(task, spec: EngineSpec, shards: Dataset,
             theta0: Optional[jax.Array] = None, *, rounds: int,
             seed: int = 0, eval_every: int = 1, mode: str = "auto",
             cohort_rng: str = "numpy") -> Dict[str, Any]:
    """Convenience one-shot: build an engine and run it."""
    return FLEngine(task, spec).run(shards, theta0, rounds=rounds, seed=seed,
                                    eval_every=eval_every, mode=mode,
                                    cohort_rng=cohort_rng)
