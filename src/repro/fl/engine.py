"""The one FL round loop: local-train -> uplink -> aggregate -> downlink.

Every training loop in the repo -- the four BiCompFL variants, BiCompFL-CFL,
and all seven non-stochastic baselines -- is an :class:`EngineSpec`
(uplink channel, downlink channel, aggregator, plus block allocation and
participation policy) executed by :class:`FLEngine`.  The engine owns the
things every scheme shares and that used to be copy-pasted per loop:

* shared-randomness key schedule (round key, per-client training keys),
* partial participation (cohort sampling; inactive clients are *not*
  trained),
* the block-allocation control plane,
* periodic error-feedback synchronisation (CSER / LIEC style ``flush``),
* BitMeter accounting and evaluation history,
* deterministic fault injection (:mod:`repro.fl.faults`) with degraded
  aggregation, retransmit accounting, and crash-safe resume
  (:mod:`repro.checkpoint`).

Two execution paths (tests/test_fused_parity.py; bit-for-bit identical
under static block plans, accuracy/bits-parity within the bucketing bound
under adaptive ones):

* **host** -- a Python round loop.  Functional channels run through a
  *staged* jit of the shared round core (one compiled stage per
  (plan-shape, fault-mode) signature, cached across rounds and runs --
  the host path stopped retracing channels every round); non-functional
  channels and ``wire="audit"`` runs use the eager shell protocol.
  Adaptive allocations recompute the *exact* plan from each round's KL
  profile on the host; this path is the parity oracle for the bucketed
  fused execution.
* **fused** -- the entire multi-round run is ONE ``jax.lax.scan`` over
  rounds: channel state (error-feedback memories) is an explicit carry
  pytree threaded through the pure ``step_up`` / ``step_down`` functions,
  evaluation folds in via ``lax.cond`` on the eval schedule, and the EF
  sync flush is a ``lax.cond`` branch.  With a *static* plan the per-round
  bits are data-independent, so communication is booked host-side after
  the scan with zero device round-trips -- the only device->host transfer
  of a whole run is the stacked accuracy vector.  With an *adaptive*
  allocation the round's KL profile is computed on device (the Pallas
  ``bernoulli_kl`` reduction via ``repro.kernels.ops``), a ``lax.switch``
  selects among the allocation's precompiled bucketed plans, and the now
  data-dependent per-round bits ride out of the scan as traced f32 vectors
  that ``BitMeter.book_run`` books after the run.

Fault injection (DESIGN.md §8): ``run(..., faults=FaultPlan(...))``
precomputes the whole fault trajectory next to the cohort schedule; both
paths consume the same tables (the host loop as Python values, the fused
scan as traced masks), so the same seed produces the identical faulted
run in either mode.  Dropped / lost clients have their error-feedback
rows and ``theta_hat`` rows *carried* (masked ``where``), surviving
contributions are renormalised through ``RoundContext.up_weight``, an
all-fail round keeps ``theta_hat`` (compute-then-discard select), and
corrupted deliveries book their wasted copies into the BitMeter's
``retransmit_bits`` category -- on the wire-audit path as actual flipped
frame copies that must fail CRC.

Crash-safe resume: ``checkpoint_dir=`` + ``checkpoint_every=`` write the
full engine carry (model, per-client estimates, channel state pytrees,
BitMeter, histories, and a config blob) through the atomic
:mod:`repro.checkpoint` writer; ``resume_from=`` restores it and
continues bit-identically -- the fused path runs *segmented* scans cut
at the same checkpoint boundaries, so an interrupted-and-resumed run
replays the exact program sequence of an uninterrupted one.

Cohort sampling is precomputed as a (rounds, n_active) schedule.
``cohort_rng="numpy"`` reproduces the seed's ``default_rng(seed+17)`` draws
(bit-compatible with the legacy loops); ``cohort_rng="jax"`` derives the
cohort from the round key (``fold_in(kt, TAG_COHORT)``), making the whole
run a pure function of ``seed`` with no host RNG.

The engine reproduces the seed loops bit-for-bit at full participation
(tests/test_engine_parity.py); see DESIGN.md for the API contract.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.core import mrc
from repro.core.bernoulli import bern_kl, clip01
from repro.core.bitmeter import BitMeter
from repro.kernels.ops import bernoulli_kl_profile, bernoulli_kl_total
from .channels import (BlockPlan, RoundContext, ServerUpdate, TAG_COHORT,
                       TAG_TRAIN, pin)
from .data import Dataset
from .faults import FaultPlan, fault_report


def _kl_stats(payload, priors, *, needs_profile: bool) -> Dict[str, Any]:
    """On-device KL statistics for the bucketed adaptive control plane.

    Mirrors the host loop's profile (per-parameter KL of the posterior
    against the client priors, averaged over the active cohort) without
    leaving the device.  On a real accelerator backend both allocation
    flavours run through the Pallas ``bernoulli_kl`` streaming reduction:
    the *mean*-only consumers (``needs_profile=False``,
    e.g. AdaptiveAvgAllocation) take
    ``repro.kernels.ops.bernoulli_kl_total``, and the full-profile
    consumers (``needs_profile=True``, AdaptiveAllocation) take
    ``repro.kernels.ops.bernoulli_kl_profile`` (parameters as kernel
    blocks, clients streaming through the reduction).  In interpret mode
    (CPU) the kernel emulation is orders of magnitude slower than the
    fused XLA elementwise reduction, so the jnp route is used there (the
    kernels' repo-wide convention: interpret=True exists to *validate* on
    CPU, not to run hot loops).  Both routes agree up to f32 summation
    order.
    """
    p = clip01(priors)
    if jax.default_backend() != "cpu":
        if needs_profile:
            klp = bernoulli_kl_profile(payload, p, interpret=False)
            return {"profile": klp, "total": jnp.sum(klp)}
        return {"profile": None,
                "total": bernoulli_kl_total(payload, p, interpret=False)}
    klp = jnp.mean(jax.vmap(bern_kl)(payload, p), axis=0)
    return {"profile": klp if needs_profile else None,
            "total": jnp.sum(klp)}


# ---------------------------------------------------------------------------
# Fault-aware helpers shared verbatim by both execution paths.
# ---------------------------------------------------------------------------


def _cohort_mean(ctx, x):
    """Mean over the cohort axis, renormalised over survivors under faults.

    On fault-free rounds ``ctx.up_weight`` is None and this is *exactly*
    ``jnp.mean`` -- the legacy expression, bit-for-bit.  Under injected
    faults the weights zero out dropped / straggling / lost-uplink rows
    and the denominator is the survivor count (guarded against the
    all-fail round, whose result the engine discards anyway).
    """
    w = getattr(ctx, "up_weight", None)
    if w is None:
        return jnp.mean(x, axis=0)
    tot = jnp.sum(w)
    den = jnp.where(tot > 0.0, tot, 1.0)
    return jnp.tensordot(w, x, axes=1) / den


def _carry_rows(prev, new, keep):
    """Keep per-client state rows only where ``keep``; carry ``prev`` rows.

    Applied leaf-wise over a channel-state pytree: leaves whose leading
    axis is the client axis are row-masked, everything else (server-side
    state, scalars) takes the new value.  Works on traced values inside
    the fused scan and on eager arrays in the host loop alike.
    """
    if new is None:
        return None
    n = keep.shape[0]
    if prev is None:
        prev = jax.tree.map(jnp.zeros_like, new)

    def sel(p, q):
        q = jnp.asarray(q)
        if q.ndim >= 1 and q.shape[0] == n:
            k = jnp.reshape(keep, (n,) + (1,) * (q.ndim - 1))
            return jnp.where(k, q, p)
        return q

    return jax.tree.map(sel, prev, new)


def _faulted_round_bits(ul_bits, dl_bits, oh_full, rf, n_active, dl_denom):
    """Scale one round's nominal bit totals by its fault view.

    Returns ``(uplink, downlink, overhead, retransmit)`` bits.  Uplink
    bills every *delivered* sender (stragglers included -- the traffic
    happened); each corrupted copy re-bills one per-client payload into
    the retransmit category; the downlink of an all-fail round never
    leaves the server; CTRL side information reaches online clients only.
    Used identically by the host loop and the fused post-scan booking so
    both paths run the same float arithmetic.
    """
    per_up = ul_bits / n_active
    per_dn = dl_bits / dl_denom if dl_denom else 0.0
    per_oh = oh_full / len(rf.online)
    ul = per_up * float(rf.delivered_up.sum())
    rt = per_up * float(rf.up_wasted.sum())
    if rf.all_failed:
        dl = 0.0
    else:
        dl = per_dn * float(rf.delivered_dn.sum())
        rt += per_dn * float(rf.dn_wasted.sum())
    oh = per_oh * float(rf.online.sum())
    return ul, dl, oh, rt


# ---------------------------------------------------------------------------
# Aggregators: uplink output -> proposed server update.
# ---------------------------------------------------------------------------


class MeanModelAggregator:
    """BiCompFL: the mean of the conveyed posterior samples *is* the model."""

    def __call__(self, ctx, theta, up_out) -> ServerUpdate:
        return ServerUpdate(theta=_cohort_mean(ctx, up_out))


@dataclass
class MeanDeltaAggregator:
    """Conventional FL: average the (compressed) deltas, step the server."""

    server_lr: float = 1.0

    def __call__(self, ctx, theta, up_out) -> ServerUpdate:
        # The mean feeds the server step; pinned so the fused engine cannot
        # FMA-contract mean's scale into the subtraction (cf. channels.pin).
        g = pin(getattr(ctx, "pin_token", None), _cohort_mean(ctx, up_out))
        return ServerUpdate(theta=theta - self.server_lr * g, delta=g,
                            lr=self.server_lr)


# ---------------------------------------------------------------------------
# Engine.
# ---------------------------------------------------------------------------


@dataclass
class EngineSpec:
    """A complete FL scheme: who compresses what, in which direction."""

    uplink: Any
    downlink: Any
    aggregator: Any
    allocation: Any = None       # block-allocation strategy (MRC schemes)
    participation: float = 1.0   # fraction of clients active per round
    sync_period: int = 0         # 0 = never; else flush EF memories every k
    name: str = ""


class FLEngine:
    """Runs an :class:`EngineSpec` against a task and sharded dataset."""

    def __init__(self, task, spec: EngineSpec):
        self.task = task
        self.spec = spec
        # Fused-program cache (satellite of the wire PR): one compiled
        # scanned-run program per (rounds, shapes) signature, so repeated
        # ``run()`` calls -- benchmark sweeps, seed replicates -- stop
        # retracing the scan body.  Each entry holds the jitted runner and
        # the trace-time ``booked`` bit record it captured.
        self._fused_programs: Dict[Any, Any] = {}
        self.fused_trace_count = 0  # bumped at trace time (regression test)
        # Host-path stage cache: one jitted round core per (plan-shape,
        # fault-mode) signature.  The same shape signature recurs every
        # round (and across runs), so the host loop stops re-tracing the
        # channels each round -- the ROADMAP "host re-trace" item.
        self._host_jits: Dict[Any, Any] = {}
        self.host_trace_count = 0   # bumped at trace time (regression test)

    # -- fused-path eligibility -------------------------------------------

    def _functional_channels(self) -> bool:
        """Both channels speak the pure-state protocol (explicit carry)."""
        spec = self.spec
        up_ok = all(hasattr(spec.uplink, a)
                    for a in ("step_up", "init_up_state", "flush_step"))
        dn_ok = all(hasattr(spec.downlink, a)
                    for a in ("step_down", "init_down_state", "flush_step"))
        return up_ok and dn_ok

    def fused_supported(self) -> bool:
        """True when the whole run can compile to one scanned XLA program.

        Only *non-functional* channels (no ``step_up`` / ``step_down``
        protocol) force the host loop.  Adaptive allocations are fused via
        their bucketed control plane (``bucket_plans`` / ``select_bucket``
        / ``finalize_plan``); an allocation exposing neither a static plan
        nor the bucket API -- or a hand-built spec combining a
        data-dependent plan with a periodic EF flush, a pairing no
        registry scheme produces (the flush would need the aggregator's
        step size inside every switch branch) -- stays host-only.
        """
        spec = self.spec
        if spec.allocation is not None and \
                not getattr(spec.allocation, "static_plan", False):
            bucket_ok = all(hasattr(spec.allocation, a) for a in
                            ("bucket_plans", "select_bucket", "finalize_plan"))
            if not bucket_ok or spec.sync_period:
                return False
        return self._functional_channels()

    # -- cohort schedule ---------------------------------------------------

    @staticmethod
    def cohort_schedule(rounds: int, n: int, n_active: int, seed: int,
                        cohort_rng: str = "numpy") -> np.ndarray:
        """Precompute the (rounds, n_active) active-cohort table.

        ``numpy`` consumes ``default_rng(seed+17)`` exactly as the seed
        loops did (one sorted no-replacement draw per round, in round
        order), so precomputing changes nothing.  ``jax`` derives each
        round's cohort from the shared round key instead.
        """
        if cohort_rng not in ("numpy", "jax"):
            raise ValueError(cohort_rng)
        if n_active >= n:
            return np.tile(np.arange(n, dtype=np.int64), (rounds, 1))
        if cohort_rng == "numpy":
            rng = np.random.default_rng(seed + 17)
            return np.stack([np.sort(rng.choice(n, size=n_active, replace=False))
                             for _ in range(rounds)])
        base = jax.random.PRNGKey(seed)

        def one(t):
            kc = jax.random.fold_in(mrc.round_key(base, t), TAG_COHORT)
            return jnp.sort(jax.random.choice(
                kc, n, (n_active,), replace=False))

        sched = jax.vmap(one)(jnp.arange(rounds))
        return np.asarray(sched, dtype=np.int64)

    # -- the shared round core --------------------------------------------

    @staticmethod
    def _round_core(spec, plan, theta, theta_hat, up_s, dn_s, payload,
                    priors, ctx):
        """Uplink -> aggregate -> downlink at one (static-shape) plan.

        The single definition both execution paths trace -- the fused
        scan body and the host loop's staged jit -- so a faulted host
        round and a faulted fused round are the *same* compiled graph.
        Every cross-stage value is pinned through ``channels.pin`` (an
        integer-space round-trip on a traced zero) so XLA cannot
        FMA-contract across stage boundaries and break host/fused
        bit-parity.
        """
        pp = ctx.pin_token
        up_out, ul_bits, up_s = spec.uplink.step_up(
            ctx, up_s, payload, priors)
        up_out, up_s = pin(pp, (up_out, up_s))
        update = spec.aggregator(ctx, theta, up_out)
        update = ServerUpdate(theta=pin(pp, update.theta),
                              delta=pin(pp, update.delta)
                              if update.delta is not None else None,
                              lr=update.lr)
        res, dn_s = spec.downlink.step_down(
            ctx, dn_s, update, theta, theta_hat)
        theta, theta_hat, dn_s = pin(pp, (res.theta, res.theta_hat, dn_s))
        oh = plan.overhead_bits * ctx.n_clients if plan is not None else 0.0
        return theta, theta_hat, up_s, dn_s, update, ul_bits, res.bits, oh

    # -- entry point -------------------------------------------------------

    def run(self, shards: Dataset, theta0: Optional[jax.Array] = None, *,
            rounds: int, seed: int = 0, eval_every: int = 1,
            mode: str = "auto", cohort_rng: str = "numpy",
            wire: Optional[str] = None,
            faults: Optional[FaultPlan] = None,
            checkpoint_dir: Optional[str] = None,
            checkpoint_every: int = 0,
            resume_from: Optional[str] = None) -> Dict[str, Any]:
        """Run the scheme.  ``mode``: "auto" (fused when eligible), "host",
        or "fused" (raises for schemes needing the host control plane).

        ``wire="audit"`` serializes every channel payload through the
        :mod:`repro.wire` bitstream each round (encode -> decode; the
        decoded values drive the trajectory, so the run certifies the
        codecs are lossless) and reconciles the BitMeter against the
        stream; host-path only.  The report lands in ``out["wire"]`` and
        the full stream in ``out["wire_session"]``.

        ``faults=FaultPlan(...)`` injects the plan's deterministic fault
        schedule (dropouts, stragglers, frame corruption) into the run;
        the event log and summary land in ``out["faults"]``.  A plan that
        draws no fault for this run leaves the trajectory bit-identical
        to ``faults=None``.

        ``checkpoint_dir=`` (+ ``checkpoint_every=k``) saves the full
        engine state every k rounds (and at the end); ``resume_from=``
        (a checkpoint file or a directory to scan for the newest valid
        step) restores it and continues bit-identically.
        """
        task, spec = self.task, self.spec
        if wire not in (None, "audit"):
            raise ValueError(f"wire={wire!r} (expected None or 'audit')")
        if wire and mode == "fused":
            raise ValueError("wire audit runs on the host path; it cannot "
                             "be combined with mode='fused'")
        if faults is not None and not isinstance(faults, FaultPlan):
            raise ValueError(f"faults={faults!r} (expected a FaultPlan)")
        if checkpoint_every and not checkpoint_dir:
            raise ValueError("checkpoint_every needs checkpoint_dir")
        if checkpoint_every < 0:
            raise ValueError(f"checkpoint_every={checkpoint_every} < 0")
        if wire and (checkpoint_dir or resume_from):
            raise ValueError("wire audit cannot checkpoint or resume (the "
                             "session stream is not part of the saved carry)")
        if (checkpoint_dir or resume_from) and not self._functional_channels():
            raise ValueError(
                f"spec {spec.name!r} cannot checkpoint/resume: channels "
                "without the pure-state protocol have no explicit carry")
        # Stateful channels (error-feedback memories) must start fresh: a
        # spec may be run more than once.
        for chan in (spec.uplink, spec.downlink):
            reset = getattr(chan, "reset", None)
            if reset is not None:
                reset()
        n = int(shards.x.shape[0])
        theta = task.init_theta() if theta0 is None else theta0
        d = int(theta.shape[0])
        theta_hat = jnp.tile(theta[None], (n, 1))
        meter = BitMeter(
            n_clients=n, d=d,
            broadcast_downlink_shareable=getattr(
                spec.downlink, "broadcast_shareable", True))
        n_active = max(1, int(round(spec.participation * n)))
        schedule = self.cohort_schedule(rounds, n, n_active, seed, cohort_rng)

        # Fault schedule: precomputed like the cohort schedule, before any
        # round work.  ``views`` stays None when the drawn schedule is
        # fault-free, keeping the run on the exact legacy code paths.
        fsched = views_all = views = None
        if faults is not None:
            fsched = faults.schedule(rounds, n)
            dl_rec = getattr(spec.downlink, "downlink_recipients", "all")
            views_all = fsched.run_views(schedule, dl_rec)
            if any(v.faulty or v.all_failed for v in views_all):
                views = views_all
        if views is not None and not wire and not self._functional_channels():
            raise ValueError(
                f"spec {spec.name!r} cannot run under faults without the "
                "pure-state channel protocol (state rows must be carried "
                "explicitly) or a wire session")
        if views is not None and wire:
            for role, chan in (("uplink", spec.uplink),
                               ("downlink", spec.downlink)):
                if not (hasattr(chan, "export_state")
                        and hasattr(chan, "import_state")):
                    raise ValueError(
                        f"spec {spec.name!r} cannot run faulted wire audit: "
                        f"{role} channel lacks export_state/import_state")

        if mode not in ("auto", "host", "fused"):
            raise ValueError(mode)
        fused_ok = self.fused_supported()
        if mode == "fused" and not fused_ok:
            raise ValueError(
                f"spec {spec.name!r} needs the host control plane "
                "(non-functional channels, an allocation without the bucket "
                "API, or a data-dependent plan combined with an EF flush)")
        fused = fused_ok and mode != "host" and not wire

        cfg_blob = None
        if checkpoint_dir or resume_from:
            cfg_blob = self._config_blob(rounds=rounds, seed=seed,
                                         eval_every=eval_every,
                                         cohort_rng=cohort_rng, n=n, d=d,
                                         faults=faults)
        start_round, carry_in, history0 = 0, None, None
        if resume_from:
            start_round, theta, theta_hat, carry_in, history0 = \
                self._load_resume(resume_from, cfg_blob, meter)

        if fused:
            out = self._run_fused(shards, theta, theta_hat, meter,
                                  rounds=rounds, seed=seed,
                                  eval_every=eval_every, schedule=schedule,
                                  views=views, start_round=start_round,
                                  carry_in=carry_in, history=history0,
                                  checkpoint_dir=checkpoint_dir,
                                  checkpoint_every=checkpoint_every,
                                  cfg_blob=cfg_blob)
        else:
            session = None
            if wire:
                from repro.wire import WireSession, scheme_wire_id
                session = WireSession(
                    scheme_id=scheme_wire_id(spec.name or "unnamed"))
            out = self._run_host(shards, theta, theta_hat, meter,
                                 rounds=rounds, seed=seed,
                                 eval_every=eval_every, schedule=schedule,
                                 session=session, views=views, fsched=fsched,
                                 start_round=start_round, carry_in=carry_in,
                                 history=history0,
                                 checkpoint_dir=checkpoint_dir,
                                 checkpoint_every=checkpoint_every,
                                 cfg_blob=cfg_blob)
            if session is not None:
                out["wire"] = session.reconcile(meter)
                out["wire_session"] = session
        out["active_schedule"] = schedule
        out["mode"] = "fused" if fused else "host"
        if faults is not None:
            rt_by_round = [h.get("retransmit_bits", 0.0)
                           for h in meter.history]
            out["faults"] = fault_report(faults, views_all, rt_by_round)
        return out

    # -- checkpoint / resume ----------------------------------------------

    def _config_blob(self, *, rounds, seed, eval_every, cohort_rng, n, d,
                     faults) -> np.ndarray:
        """Run configuration as a uint8 JSON blob (a checkpoint leaf).

        Saved with every checkpoint and compared bytewise on resume: a
        checkpoint only resumes the *same* run (spec, rounds, seed, fault
        plan), because everything the engine recomputes from scratch --
        cohort schedule, fault schedule, round keys -- must re-derive
        identically for the continuation to be bit-exact.
        """
        spec = self.spec
        cfg = {
            "kind": "fl-engine-checkpoint",
            "format": 1,
            "spec": spec.name,
            "rounds": int(rounds),
            "seed": int(seed),
            "eval_every": int(eval_every),
            "cohort_rng": cohort_rng,
            "n": int(n),
            "d": int(d),
            "participation": float(spec.participation),
            "sync_period": int(spec.sync_period),
            "faults": None if faults is None else asdict(faults),
        }
        raw = json.dumps(cfg, sort_keys=True).encode("utf-8")
        return np.frombuffer(raw, np.uint8).copy()

    def _save_state(self, directory, next_round, theta, theta_hat, up_s,
                    dn_s, meter, history, cfg_blob) -> None:
        """Write the full engine carry as one atomic per-step checkpoint."""
        mh = meter.history
        state = {
            "config": cfg_blob,
            "next_round": np.int64(next_round),
            "theta": np.asarray(theta),
            "theta_hat": np.asarray(theta_hat),
            "up_state": jax.tree.map(np.asarray, up_s),
            "dn_state": jax.tree.map(np.asarray, dn_s),
            "meter": {
                "uplink_bits": np.float64(meter.uplink_bits),
                "downlink_bits": np.float64(meter.downlink_bits),
                "retransmit_bits": np.float64(meter.retransmit_bits),
                "rounds": np.int64(meter.rounds),
                "hist_round": np.asarray([h["round"] for h in mh], np.int64),
                "hist_up": np.asarray([h["uplink_bits"] for h in mh],
                                      np.float64),
                "hist_dn": np.asarray([h["downlink_bits"] for h in mh],
                                      np.float64),
                "hist_rt": np.asarray([h.get("retransmit_bits", 0.0)
                                       for h in mh], np.float64),
                "hist_cum": np.asarray([h["cum_bits"] for h in mh],
                                       np.float64),
            },
            "history": {
                "round": np.asarray([h["round"] for h in history], np.int64),
                "acc": np.asarray([h["acc"] for h in history], np.float64),
                "cum_bits": np.asarray([h["cum_bits"] for h in history],
                                       np.float64),
                "bpp": np.asarray([h["bpp_so_far"] for h in history],
                                  np.float64),
            },
        }
        ckpt.save_step(directory, state, int(next_round))

    def _load_resume(self, resume_from, cfg_blob, meter):
        """Restore ``(start_round, theta, theta_hat, carry, history)``.

        ``resume_from`` is a checkpoint file, or a directory whose newest
        *valid* step checkpoint is chosen (torn files are skipped with a
        warning by :func:`repro.checkpoint.latest`).  The saved config
        blob must match this run's exactly.
        """
        if os.path.isdir(resume_from):
            path, _ = ckpt.latest(resume_from)
            if path is None:
                raise ValueError(
                    f"resume_from={resume_from!r}: no valid checkpoint found")
        else:
            path = resume_from
        state, _ = ckpt.load(path)
        saved = bytes(np.asarray(state["config"], np.uint8))
        if saved != bytes(np.asarray(cfg_blob, np.uint8)):
            raise ValueError(
                f"checkpoint {path} was saved by a different run "
                "configuration (spec/rounds/seed/faults must be identical "
                "to resume)")
        m = state["meter"]
        meter.uplink_bits = float(m["uplink_bits"])
        meter.downlink_bits = float(m["downlink_bits"])
        meter.retransmit_bits = float(m["retransmit_bits"])
        meter.rounds = int(m["rounds"])
        meter.history = []
        for r, u, dl, rt, cum in zip(m["hist_round"], m["hist_up"],
                                     m["hist_dn"], m["hist_rt"],
                                     m["hist_cum"]):
            entry = {"round": int(r), "uplink_bits": float(u),
                     "downlink_bits": float(dl), "cum_bits": float(cum)}
            if rt:  # key present only when nonzero, as add_round writes it
                entry["retransmit_bits"] = float(rt)
            meter.history.append(entry)
        h = state["history"]
        history0 = [{"round": int(r), "acc": float(a), "cum_bits": float(c),
                     "bpp_so_far": float(b)}
                    for r, a, c, b in zip(h["round"], h["acc"],
                                          h["cum_bits"], h["bpp"])]
        theta = jnp.asarray(state["theta"])
        theta_hat = jnp.asarray(state["theta_hat"])
        carry = (jax.tree.map(jnp.asarray, state["up_state"]),
                 jax.tree.map(jnp.asarray, state["dn_state"]))
        return (int(np.asarray(state["next_round"])), theta, theta_hat,
                carry, history0)

    # -- host loop ---------------------------------------------------------

    def _stage_round(self, plan, faulted, n, d, n_active):
        """Cached jit of the shared round core for the host loop.

        Keyed on the plan's *shape* (block size / count / segmented or
        not), the fault mode, and the run dims -- everything that changes
        the traced graph.  Round index, key, cohort, segment ids and
        fault weights ride in as traced arguments, so every round of a
        run (and repeated runs) reuse one compiled stage.  The returned
        ``rec`` dict holds the trace-time Python-float bit totals (bits
        are data-independent under a static plan; ``float()`` on a traced
        value would fail loudly).
        """
        pkey = None if plan is None else (
            plan.size, int(plan.n_blocks), plan.seg_ids is not None,
            getattr(plan, "billable_blocks", None))
        key = ("round", pkey, faulted, n, d, n_active)
        hit = self._host_jits.get(key)
        if hit is not None:
            return hit
        spec = self.spec
        rec: Dict[str, float] = {}
        has_plan = plan is not None
        size = plan.size if has_plan else None
        n_blocks = int(plan.n_blocks) if has_plan else None
        billable = getattr(plan, "billable_blocks", None) if has_plan else None

        def stage(kt, t, active, ptok, seg, w, theta, theta_hat, up_s, dn_s,
                  payload, priors):
            self.host_trace_count += 1  # Python side effect: trace-time only
            p = None
            if has_plan:
                p = BlockPlan(size=size, n_blocks=n_blocks, seg_ids=seg,
                              overhead_bits=0.0, billable_blocks=billable)
            ctx = RoundContext(t=t, key=kt, n_clients=n, d=d, active=active,
                               plan=p, pin_token=ptok, up_weight=w)
            th, thh, us, ds, update, ul_bits, dl_bits, _ = self._round_core(
                spec, p, theta, theta_hat, up_s, dn_s, payload, priors, ctx)
            rec["ul"] = float(ul_bits)
            rec["dl"] = float(dl_bits)
            rec["lr"] = float(update.lr)
            return th, thh, us, ds

        entry = (jax.jit(stage), rec)
        self._host_jits[key] = entry
        return entry

    def _run_host(self, shards, theta, theta_hat, meter, *, rounds, seed,
                  eval_every, schedule, session=None, views=None,
                  fsched=None, start_round=0, carry_in=None, history=None,
                  checkpoint_dir=None, checkpoint_every=0,
                  cfg_blob=None) -> Dict[str, Any]:
        task, spec = self.task, self.spec
        n, d = meter.n_clients, meter.d
        n_active = schedule.shape[1]
        base = jax.random.PRNGKey(seed)
        history = list(history) if history else []
        faulted = views is not None
        dl_rec = getattr(spec.downlink, "downlink_recipients", "all")
        dl_denom = n if dl_rec == "all" else n_active
        if session is not None:
            self._check_wire_support()
        # Functional channels run through the cached staged jit (explicit
        # state carry, fault masks applied host-side between stages); the
        # wire-audit path and non-functional channels keep the eager shell
        # protocol.
        staged = session is None and self._functional_channels()
        up_s = dn_s = None
        if staged:
            if carry_in is not None:
                up_s, dn_s = carry_in
            else:
                up_s = spec.uplink.init_up_state(n, d)
                dn_s = spec.downlink.init_down_state(n, d)

        for t in range(start_round, rounds):
            kt = mrc.round_key(base, t)
            active = schedule[t]
            rf = views[t] if faulted else None
            msgs = []  # this round's wire traffic (audit mode only)

            # ---- local training: only the active cohort ------------------
            train_keys = jax.random.split(jax.random.fold_in(kt, TAG_TRAIN), n)
            if n_active < n:
                priors = theta_hat[active]
                xs, ys, keys = (shards.x[active], shards.y[active],
                                train_keys[active])
            else:  # full participation: no device-side gather/copy needed
                priors, xs, ys, keys = theta_hat, shards.x, shards.y, train_keys
            payload = jax.vmap(task.local_train)(priors, xs, ys, keys)

            # ---- block allocation (host-side control plane) --------------
            plan = None
            if spec.allocation is not None:
                kl = None
                if getattr(spec.allocation, "needs_kl", True):
                    kl = np.asarray(jnp.mean(jax.vmap(bern_kl)(
                        payload, clip01(priors)), axis=0))
                size, n_blocks, seg_ids, overhead = spec.allocation.plan(kl, d)
                plan = BlockPlan(size=size, n_blocks=n_blocks,
                                 seg_ids=seg_ids, overhead_bits=overhead)
                if session is not None:
                    # The plan side information crosses the wire as one CTRL
                    # frame per client (the meter books overhead_bits * n);
                    # the decoded plan -- not the host object -- drives the
                    # round, certifying the header codec.  Under faults the
                    # CTRL link is protected signalling: never corrupted,
                    # but dropped clients miss their copy.
                    ctrl = self._encode_plan_msgs(plan, n)
                    plan = self._decode_plan_msg(ctrl[0], d)
                    msgs += [m for m in ctrl
                             if not faulted or rf.online[m.sender]]

            if staged:
                tj = jnp.asarray(t, jnp.int32)
                aj = jnp.asarray(active)
                ptok = jnp.zeros((), jnp.int32)  # pins must fire inside jit
                seg = None if plan is None or plan.seg_ids is None \
                    else jnp.asarray(plan.seg_ids)
                w = jnp.asarray(rf.up_weight) if faulted else None
                fn, rec = self._stage_round(plan, faulted, n, d, n_active)
                th, thh, us, ds = fn(kt, tj, aj, ptok, seg, w, theta,
                                     theta_hat, up_s, dn_s, payload, priors)
                ul_bits, dl_bits, lr = rec["ul"], rec["dl"], rec["lr"]
                if faulted:
                    # Carried, not corrupted: dropped/lost rows keep their
                    # pre-round EF state and theta_hat estimate; an
                    # all-fail round discards the whole computed step.
                    us = _carry_rows(up_s, us, jnp.asarray(rf.delivered_up))
                    thh = jnp.where(jnp.asarray(rf.delivered_dn)[:, None],
                                    thh, theta_hat)
                    if rf.all_failed:
                        th, thh, us, ds = theta, theta_hat, up_s, dn_s
                theta, theta_hat, up_s, dn_s = th, thh, us, ds
                oh_full = plan.overhead_bits * n if plan is not None else 0.0
                if faulted:
                    ul_r, dl_r, oh_r, rt_r = _faulted_round_bits(
                        ul_bits, dl_bits, oh_full, rf, n_active, dl_denom)
                else:
                    ul_r, dl_r, oh_r, rt_r = ul_bits, dl_bits, oh_full, 0.0
                # ---- periodic EF synchronisation (CSER / LIEC) -----------
                # The flush is protected signalling: exempt from faults,
                # booked unscaled.
                if spec.sync_period and (t + 1) % spec.sync_period == 0:
                    r_up, b_up, up_s = spec.uplink.flush_step(up_s, n, d)
                    r_dn, b_dn, dn_s = spec.downlink.flush_step(dn_s, n, d)
                    theta = theta - lr * (r_up + r_dn)
                    theta_hat = jnp.tile(theta[None], (n, 1))
                    ul_r += b_up
                    dl_r += b_dn
                meter.add_round(ul_r, dl_r, overhead_bits=oh_r,
                                retransmit_bits=rt_r)
            else:
                theta, theta_hat = self._shell_round(
                    t, kt, active, plan, payload, priors, theta, theta_hat,
                    meter, session, msgs, rf, fsched, n, d, n_active,
                    dl_denom)
            if session is not None:
                session.add(msgs, round=t)

            if (t + 1) % eval_every == 0 or t == rounds - 1:
                acc = task.evaluate(theta)
                history.append({"round": t + 1, "acc": float(acc),
                                "cum_bits": meter.total_bits,
                                "bpp_so_far": meter.total_bpp})
            if staged and checkpoint_dir and (
                    (checkpoint_every and (t + 1) % checkpoint_every == 0)
                    or t + 1 == rounds):
                self._save_state(checkpoint_dir, t + 1, theta, theta_hat,
                                 up_s, dn_s, meter, history, cfg_blob)

        return self._result(history, meter, theta, theta_hat)

    def _shell_round(self, t, kt, active, plan, payload, priors, theta,
                     theta_hat, meter, session, msgs, rf, fsched, n, d,
                     n_active, dl_denom):
        """One eager shell-protocol round (wire audit / non-functional).

        Appends this round's frames to ``msgs`` (mutated in place) and
        books the meter.  ``rf`` is the round's fault view or None; a
        faulted shell round always has a wire session (enforced in
        ``run``), injects real corrupted frame copies, and books bits
        from the stream itself so the session reconciles exactly.
        """
        spec = self.spec
        faulted = rf is not None
        if faulted:
            up_snap = spec.uplink.export_state()
            dn_snap = spec.downlink.export_state()
            n_wasted0 = len(session.wasted)
        ctx = RoundContext(t=t, key=kt, n_clients=n, d=d, active=active,
                           plan=plan,
                           up_weight=jnp.asarray(rf.up_weight)
                           if faulted else None)

        # ---- uplink -> aggregate -> downlink -----------------------------
        if session is None:
            up_out, ul_bits = spec.uplink.transmit(ctx, payload, priors)
        else:
            up_out, ul_bits, up_msgs = spec.uplink.transmit_wire(
                ctx, payload, priors)
            up_out = spec.uplink.decode_up(ctx, up_msgs, priors)
            if faulted:
                spec.uplink.import_state(_carry_rows(
                    up_snap, spec.uplink.export_state(),
                    jnp.asarray(rf.delivered_up)))
                msgs += self._wire_deliver(
                    session, fsched, rf, t, up_msgs, owner="sender", link=0,
                    sched=rf.senders, ok=rf.delivered_up,
                    wasted=rf.up_wasted)
            else:
                msgs += up_msgs
        update = spec.aggregator(ctx, theta, up_out)
        if session is None:
            theta, theta_hat, dl_bits = spec.downlink.distribute(
                ctx, update, theta, theta_hat)
        elif faulted and rf.all_failed:
            # Compute-then-discard: the server aborts before broadcasting,
            # every client (and the channel state) keeps its pre-round
            # view; only the uplink traffic that did happen is billed.
            spec.uplink.import_state(up_snap)
            spec.downlink.import_state(dn_snap)
            dl_bits = 0.0
        else:
            from .channels import WireEnv
            _, dn_msgs = spec.downlink.distribute_wire(
                ctx, update, theta, theta_hat, up_msgs)
            env = WireEnv(uplink=spec.uplink, aggregator=spec.aggregator,
                          priors=priors, up_msgs=up_msgs, update=update)
            new_th, new_hat, dl_bits = spec.downlink.decode_down(
                ctx, dn_msgs, theta, theta_hat, env)
            if faulted:
                theta = new_th
                theta_hat = jnp.where(jnp.asarray(rf.delivered_dn)[:, None],
                                      new_hat, theta_hat)
                msgs += self._wire_deliver(
                    session, fsched, rf, t, dn_msgs, owner="recipient",
                    link=1, sched=rf.nominal_recv & rf.online,
                    ok=rf.delivered_dn, wasted=rf.dn_wasted)
            else:
                theta, theta_hat = new_th, new_hat
                msgs += dn_msgs

        # ---- periodic EF synchronisation (CSER / LIEC) -------------------
        if spec.sync_period and (t + 1) % spec.sync_period == 0:
            if session is None:
                r_up, b_up = spec.uplink.flush(n, d)
            else:
                r_up, b_up, fl_msgs = spec.uplink.flush_wire(n, d)
                if fl_msgs:
                    r_up = spec.uplink.decode_flush_up(fl_msgs, n, d)
                msgs += fl_msgs
            r_dn, b_dn = spec.downlink.flush(n, d)
            # flush at the aggregator's step size (update.lr), so a
            # hand-built spec cannot desync the reset from the rounds
            theta = theta - update.lr * (r_up + r_dn)
            theta_hat = jnp.tile(theta[None], (n, 1))
            ul_bits += b_up
            dl_bits += b_dn
            if session is not None and b_dn:
                # The downlink flush re-broadcasts the synced model: n
                # dense frames of the post-flush theta, n * d * 32 bits
                # == every stateful downlink's booked flush cost.  The
                # decoded broadcast drives the trajectory.
                fd_msgs, theta = self._flush_down_msgs(theta, n, d, b_dn)
                theta_hat = jnp.tile(theta[None], (n, 1))
                msgs += fd_msgs

        if faulted:
            # Book straight from the frames that actually hit the stream
            # (CTRL overhead rides the uplink direction), so the session
            # reconcile is exact by construction.
            from repro.wire import DOWNLINK_DIRS, UPLINK_DIRS
            ul_r = float(sum(m.payload_bits for m in msgs
                             if m.direction in UPLINK_DIRS))
            dl_r = float(sum(m.payload_bits for m in msgs
                             if m.direction in DOWNLINK_DIRS))
            rt_r = float(sum(wa.payload_bits
                             for wa in session.wasted[n_wasted0:]))
            meter.add_round(ul_r, dl_r, retransmit_bits=rt_r)
        else:
            overhead_bits = plan.overhead_bits * n if plan is not None else 0.0
            meter.add_round(ul_bits, dl_bits, overhead_bits=overhead_bits)
        return theta, theta_hat

    def _wire_deliver(self, session, fsched, rf, t, msgs, *, owner, link,
                      sched, ok, wasted):
        """Route one direction's frames through the faulty link.

        For every scheduled frame, materialize each corrupted copy the
        fault schedule drew (flip the scheduled bit, *prove* the CRC
        rejects it, book it as a wasted attempt), then deliver the clean
        frame iff the retry budget survived.  Returns the delivered
        frames.
        """
        from repro.wire import Message, WireError
        from .faults import corrupt_copy
        delivered = []
        for m in msgs:
            cid = getattr(m, owner)
            if not sched[cid]:
                continue
            for a in range(int(wasted[cid])):
                stamped = Message(direction=m.direction, sender=m.sender,
                                  recipient=m.recipient, payload=m.payload,
                                  payload_bits=m.payload_bits, round=t,
                                  scheme_id=session.scheme_id)
                raw = stamped.to_bytes()
                bit = fsched.flip_bit(t, cid, link, a, 8 * len(raw))
                try:
                    Message.from_bytes(corrupt_copy(raw, bit))
                except WireError:
                    pass
                else:
                    raise AssertionError(
                        f"corrupted frame copy (round {t}, client {cid}, "
                        f"bit {bit}) parsed cleanly: the CRC failed to "
                        "catch the flip")
                session.add_wasted(stamped, round=t, attempt=a,
                                   flipped_bit=bit)
            if ok[cid]:
                delivered.append(m)
        return delivered

    # -- wire-audit helpers ------------------------------------------------

    def _check_wire_support(self) -> None:
        spec = self.spec
        missing = [a for a in ("transmit_wire", "decode_up")
                   if not hasattr(spec.uplink, a)]
        missing += [a for a in ("distribute_wire", "decode_down")
                    if not hasattr(spec.downlink, a)]
        if spec.allocation is not None and not all(
                hasattr(spec.allocation, a)
                for a in ("encode_plan", "decode_plan")):
            missing.append("allocation.encode_plan/decode_plan")
        if missing:
            raise ValueError(
                f"spec {spec.name!r} cannot be wire-audited: missing "
                f"{missing}")
        # Fail before any round work: a non-power-of-two n_is books
        # fractional bits per index and would only surface as a
        # WireCapacityError from codecs.index_width mid-run.
        from repro.wire.codecs import WireCapacityError, index_width
        for role, chan in (("uplink", spec.uplink),
                           ("downlink", spec.downlink)):
            n_is = getattr(chan, "n_is", None)
            if n_is is None:
                continue
            try:
                index_width(n_is)
            except WireCapacityError as e:
                raise ValueError(
                    f"spec {spec.name!r} cannot be wire-audited: {role} "
                    f"channel {type(chan).__name__} has n_is={n_is}, "
                    "which books fractional bits per MRC index; wire "
                    "codecs need a power of two") from e

    def _encode_plan_msgs(self, plan, n):
        from repro.wire import DIR_CTRL, BitWriter, SERVER, Message
        w = BitWriter()
        self.spec.allocation.encode_plan(plan, w)
        payload, nbits = w.getvalue(), w.bits_written
        return [Message(direction=DIR_CTRL, sender=cid, recipient=SERVER,
                        payload=payload, payload_bits=nbits)
                for cid in range(n)]

    def _decode_plan_msg(self, msg, d):
        from repro.wire import BitReader
        r = BitReader(msg.payload, msg.payload_bits)
        plan = self.spec.allocation.decode_plan(r, d)
        r.expect_exhausted()
        return plan

    def _flush_down_msgs(self, theta, n, d, b_dn):
        from repro.wire import DIR_FLUSH_DOWN, BitWriter, BitReader, \
            SERVER, Message
        from repro.wire import codecs as wcodecs
        if b_dn != n * d * 32:
            raise ValueError(
                f"downlink flush books {b_dn} bits; the wire layer only "
                f"knows the dense re-broadcast protocol ({n * d * 32} bits)")
        w = BitWriter()
        wcodecs.put_dense(w, np.asarray(theta))
        payload, nbits = w.getvalue(), w.bits_written
        msgs = [Message(direction=DIR_FLUSH_DOWN, sender=SERVER,
                        recipient=cid, payload=payload, payload_bits=nbits)
                for cid in range(n)]
        r = BitReader(msgs[0].payload, msgs[0].payload_bits)
        theta = jnp.asarray(wcodecs.get_dense(r, d))
        r.expect_exhausted()
        return msgs, theta

    # -- fused loop: the whole run is one lax.scan over rounds -------------

    def _build_fused(self, *, rounds, n, d, n_active, faulted=False):
        """Build (jitted runner, trace-time booked-bits record) for one
        run signature.  Everything round-varying (seed key, cohort
        schedule, eval/flush masks, fault masks, carry, model/dataset
        arrays) is a runner *argument*; the spec, plans and shapes are
        baked into the trace.  With ``faulted`` the scan consumes the
        precomputed fault tables as extra per-round xs (weights, keep
        masks, the all-fail flag) -- the identical tables the host loop
        reads, so both modes produce the same faulted trajectory.
        """
        task, spec = self.task, self.spec
        full = n_active == n
        alloc = spec.allocation
        adaptive = alloc is not None and \
            not getattr(alloc, "static_plan", False)
        if adaptive:
            # Bucketed control plane: one lax.switch branch per static plan.
            plans = alloc.bucket_plans(d)
        elif alloc is not None:  # static: plan once for all rounds
            size, n_blocks, seg_ids, overhead = alloc.plan(None, d)
            plans = [BlockPlan(size=size, n_blocks=n_blocks, seg_ids=seg_ids,
                               overhead_bits=overhead)]
        else:
            plans = [None]

        # Static plans: bits are data-independent, so the single trace of
        # the scan body records the per-round (and per-flush) totals as
        # plain floats and the meter never touches the device.  Adaptive
        # plans: bits depend on the round's bucket, so the scan emits them
        # as traced f32 per-round vectors instead.
        booked: Dict[str, Any] = {}

        def run_fn(base, carry0, sx, sy, xs_all):
            self.fused_trace_count += 1  # Python side effect: trace-time only

            def body(carry, xs):
                theta, theta_hat, up_s, dn_s = carry
                prev = carry  # pre-round view: what faults carry forward
                kt = mrc.round_key(base, xs["t"])
                active = xs["active"]
                pp = xs["pin"]  # traced int32 zero: the rounding pin token
                w = xs["w"] if faulted else None

                train_keys = jax.random.split(
                    jax.random.fold_in(kt, TAG_TRAIN), n)
                if full:
                    priors, bx, by, keys = theta_hat, sx, sy, train_keys
                else:
                    priors = theta_hat[active]
                    bx, by, keys = sx[active], sy[active], train_keys[active]
                payload = pin(pp, jax.vmap(task.local_train)(
                    priors, bx, by, keys))

                def make_ctx(plan):
                    return RoundContext(t=xs["t"], key=kt, n_clients=n, d=d,
                                        active=active, plan=plan,
                                        pin_token=pp, up_weight=w)

                if adaptive:
                    stats = _kl_stats(payload, priors,
                                      needs_profile=getattr(
                                          alloc, "needs_profile", True))
                    bidx = alloc.select_bucket(stats, d)

                    def make_branch(template):
                        def branch(op):
                            th, thh, us, ds = op
                            plan = alloc.finalize_plan(template, stats, d)
                            th, thh, us, ds, _, ulb, dlb, oh = \
                                self._round_core(spec, plan, th, thh, us, ds,
                                                 payload, priors,
                                                 make_ctx(plan))
                            bits = tuple(jnp.asarray(b, jnp.float32)
                                         for b in (ulb, dlb, oh))
                            return th, thh, us, ds, bits
                        return branch

                    theta, theta_hat, up_s, dn_s, bits = jax.lax.switch(
                        bidx, [make_branch(p) for p in plans],
                        (theta, theta_hat, up_s, dn_s))
                    update = None
                else:
                    theta, theta_hat, up_s, dn_s, update, ul_bits, dl_bits, \
                        oh = self._round_core(spec, plans[0], theta,
                                              theta_hat, up_s, dn_s, payload,
                                              priors, make_ctx(plans[0]))
                    booked["round"] = (ul_bits, dl_bits, oh)
                    bits = ()

                if faulted:
                    # Same masking order as the host loop: theta_hat rows
                    # that missed the downlink keep the pre-round value,
                    # EF rows of undelivered uplinks are carried, and the
                    # whole step is discarded on an all-fail round.
                    theta_hat = jnp.where(xs["recv"][:, None], theta_hat,
                                          prev[1])
                    up_s = _carry_rows(prev[2], up_s, xs["keep_up"])
                    ok = xs["ok"]
                    theta, theta_hat, up_s, dn_s = jax.tree.map(
                        lambda nw, od: jnp.where(ok, nw, od),
                        (theta, theta_hat, up_s, dn_s), prev)

                if not adaptive and spec.sync_period:
                    def do_flush(op):
                        th, thh, us, ds = op
                        r_up, b_up, us = spec.uplink.flush_step(us, n, d)
                        r_dn, b_dn, ds = spec.downlink.flush_step(
                            ds, n, d)
                        booked["flush"] = (b_up, b_dn)
                        # residual means
                        r_up, r_dn = pin(pp, (r_up, r_dn))
                        th = th - update.lr * (r_up + r_dn)
                        return pin(pp, (th, jnp.tile(th[None], (n, 1)),
                                        us, ds))

                    theta, theta_hat, up_s, dn_s = jax.lax.cond(
                        xs["flush"], do_flush, lambda op: op,
                        (theta, theta_hat, up_s, dn_s))

                acc = jax.lax.cond(
                    xs["eval"],
                    lambda th: jnp.asarray(task.evaluate(th), jnp.float32),
                    lambda th: jnp.full((), jnp.nan, jnp.float32), theta)
                return (theta, theta_hat, up_s, dn_s), (acc,) + bits

            return jax.lax.scan(body, carry0, xs_all)

        return jax.jit(run_fn), booked

    def _run_fused(self, shards, theta, theta_hat, meter, *, rounds, seed,
                   eval_every, schedule, views=None, start_round=0,
                   carry_in=None, history=None, checkpoint_dir=None,
                   checkpoint_every=0, cfg_blob=None) -> Dict[str, Any]:
        spec = self.spec
        n, d = meter.n_clients, meter.d
        n_active = schedule.shape[1]
        alloc = spec.allocation
        adaptive = alloc is not None and \
            not getattr(alloc, "static_plan", False)
        faulted = views is not None
        dl_rec = getattr(spec.downlink, "downlink_recipients", "all")
        dl_denom = n if dl_rec == "all" else n_active

        eval_mask = np.zeros(rounds, bool)
        eval_mask[eval_every - 1::eval_every] = True
        if rounds:
            eval_mask[-1] = True
        flush_mask = np.zeros(rounds, bool)
        if spec.sync_period:
            flush_mask[spec.sync_period - 1::spec.sync_period] = True

        if carry_in is not None:
            up_s0, dn_s0 = carry_in
        else:
            up_s0 = spec.uplink.init_up_state(n, d)
            dn_s0 = spec.downlink.init_down_state(n, d)
        carry = (theta, theta_hat, up_s0, dn_s0)

        xs_full = {"t": jnp.arange(rounds, dtype=jnp.int32),
                   "active": jnp.asarray(schedule),
                   "eval": jnp.asarray(eval_mask),
                   "flush": jnp.asarray(flush_mask),
                   "pin": jnp.zeros(rounds, jnp.int32)}
        if faulted:
            xs_full["w"] = jnp.asarray(
                np.stack([v.up_weight for v in views]))
            xs_full["keep_up"] = jnp.asarray(
                np.stack([v.delivered_up for v in views]))
            xs_full["recv"] = jnp.asarray(
                np.stack([v.delivered_dn for v in views]))
            xs_full["ok"] = jnp.asarray(
                np.asarray([not v.all_failed for v in views]))

        # Checkpoint boundaries segment the scan: an uninterrupted
        # checkpointed run and a killed-and-resumed one execute the same
        # program sequence over the same carries, hence are bit-identical.
        bounds = set()
        if checkpoint_dir and checkpoint_every:
            first = ((start_round // checkpoint_every) + 1) * checkpoint_every
            bounds = set(range(first, rounds, checkpoint_every))
        cuts = sorted(bounds | {rounds})
        history = list(history) if history else []
        base = jax.random.PRNGKey(seed)
        s = start_round
        if s >= rounds:
            return self._result(history, meter, theta, theta_hat)
        for e in cuts:
            if e <= s:
                continue
            L = e - s
            # One compiled program per segment signature: the seed, cohort
            # schedule, fault tables and eval/flush masks ride in as
            # *data*, so seed replicates and eval-cadence changes hit the
            # cache; only a shape change (segment length, client count,
            # model size, dataset shard dims, fault mode) builds a new
            # program.
            sig = (L, n, d, n_active, faulted,
                   tuple(shards.x.shape), str(shards.x.dtype),
                   tuple(shards.y.shape), str(shards.y.dtype),
                   tuple(theta.shape), str(theta.dtype))
            prog = self._fused_programs.get(sig)
            if prog is None:
                prog = self._build_fused(rounds=L, n=n, d=d,
                                         n_active=n_active, faulted=faulted)
                self._fused_programs[sig] = prog
            fn, booked = prog
            xs = {k: v[s:e] for k, v in xs_full.items()}
            carry, outs = fn(base, carry, shards.x, shards.y, xs)
            seg_eval = eval_mask[s:e]

            if adaptive:
                # Traced-bits booking: the scan's stacked per-round bit
                # totals are the only extra device->host transfer.  They
                # are exact as long as they stay below 2**24 -- every term
                # is an integer times log2 of a pow2 n_is, and f32
                # represents integers exactly up to there -- so guard the
                # bound loudly instead of letting the accounting drift
                # silently at larger scales.
                accs, ul, dl, oh = (np.asarray(o) for o in outs)
                if max((float(np.max(np.abs(v))) if v.size else 0.0)
                       for v in (ul, dl, oh)) >= 2.0 ** 24:
                    raise OverflowError(
                        "per-round traced bits exceed the f32 integer-exact "
                        "range (2**24); run mode='host' for exact accounting "
                        "at this scale")
                ul64 = np.asarray(ul, np.float64)
                dl64 = np.asarray(dl, np.float64)
                oh64 = np.asarray(oh, np.float64)
                if faulted:
                    rows = [_faulted_round_bits(
                        float(ul64[i]), float(dl64[i]), float(oh64[i]),
                        views[s + i], n_active, dl_denom)
                        for i in range(L)]
                    snaps = meter.book_run(
                        [r[0] for r in rows], [r[1] for r in rows],
                        overhead_bits=[r[2] for r in rows],
                        retransmit_bits=[r[3] for r in rows],
                        snapshot_mask=seg_eval)
                else:
                    snaps = meter.book_run(ul64, dl64, overhead_bits=oh64,
                                           snapshot_mask=seg_eval)
            else:
                # Host-side booking with zero device involvement.
                (accs,) = outs
                accs = np.asarray(accs)
                ul_base, dl_base, oh = booked["round"]
                fl_up, fl_dn = booked.get("flush", (0.0, 0.0))
                if faulted:
                    uls, dls, ohs, rts = [], [], [], []
                    for t in range(s, e):
                        u_, d_, o_, r_ = _faulted_round_bits(
                            ul_base, dl_base, oh, views[t], n_active,
                            dl_denom)
                        if flush_mask[t]:  # flush is protected: unscaled
                            u_ += fl_up
                            d_ += fl_dn
                        uls.append(u_)
                        dls.append(d_)
                        ohs.append(o_)
                        rts.append(r_)
                    snaps = meter.book_run(uls, dls, overhead_bits=ohs,
                                           retransmit_bits=rts,
                                           snapshot_mask=seg_eval)
                else:
                    snaps = meter.book_run(
                        [ul_base + (fl_up if flush_mask[t] else 0.0)
                         for t in range(s, e)],
                        [dl_base + (fl_dn if flush_mask[t] else 0.0)
                         for t in range(s, e)],
                        overhead_bits=oh, snapshot_mask=seg_eval)
            history += [
                {"round": int(s + i) + 1, "acc": float(accs[i]),
                 "cum_bits": cum_bits, "bpp_so_far": bpp}
                for i, (cum_bits, bpp) in zip(np.nonzero(seg_eval)[0], snaps)]
            if checkpoint_dir and (e in bounds or e == rounds):
                th_c, thh_c, us_c, ds_c = carry
                self._save_state(checkpoint_dir, e, th_c, thh_c, us_c, ds_c,
                                 meter, history, cfg_blob)
            s = e
        theta, theta_hat = carry[0], carry[1]
        return self._result(history, meter, theta, theta_hat)

    @staticmethod
    def _result(history, meter, theta, theta_hat) -> Dict[str, Any]:
        return {"history": history, "meter": meter.summary(),
                "theta": theta, "theta_hat": theta_hat,
                "final_acc": history[-1]["acc"] if history else float("nan"),
                "max_acc": max(h["acc"] for h in history)
                if history else float("nan")}


def run_spec(task, spec: EngineSpec, shards: Dataset,
             theta0: Optional[jax.Array] = None, *, rounds: int,
             seed: int = 0, eval_every: int = 1, mode: str = "auto",
             cohort_rng: str = "numpy", **kwargs) -> Dict[str, Any]:
    """Convenience one-shot: build an engine and run it."""
    return FLEngine(task, spec).run(shards, theta0, rounds=rounds, seed=seed,
                                    eval_every=eval_every, mode=mode,
                                    cohort_rng=cohort_rng, **kwargs)
