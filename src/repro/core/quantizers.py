"""Stochastic quantizers and baseline compressors.

The CFL path of BiCompFL composes a stochastic quantizer Q_s( . ) -- which
turns a real gradient into a vector of Bernoulli posteriors -- with MRC.
This module implements:

* ``stochastic_sign``      : the paper's stochastic SignSGD posterior
                             q_e = 1 / (1 + exp(-g_e / K)), values {+1,-1}.
* ``qsgd``                  : Alistarh et al. (2017) Q_s with s levels; the
                             fractional part is the Bernoulli posterior.
* deterministic baselines used by the benchmark schemes: ``sign``, ``topk``,
  ``randk`` -- plus error-feedback helpers.

All functions operate on flat vectors; the FL runtime flattens pytrees.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bernoulli import clip01


# ---------------------------------------------------------------------------
# Stochastic quantizers (gradient -> Bernoulli posterior)
# ---------------------------------------------------------------------------


class SignPosterior(NamedTuple):
    q: jax.Array  # Bernoulli parameter of "take +1"

    def value(self, bits: jax.Array) -> jax.Array:
        """Map MRC bits {0,1} (or their mean in [0,1]) to gradient values."""
        return 2.0 * bits - 1.0


def stochastic_sign(g: jax.Array, *, temperature: float = 1.0) -> SignPosterior:
    """Stochastic SignSGD: q_e = sigmoid(g_e / K)."""
    return SignPosterior(q=clip01(jax.nn.sigmoid(g / temperature)))


class QsgdPosterior(NamedTuple):
    q: jax.Array        # Bernoulli parameter ("round up")
    norm: jax.Array     # ||g||  (scalar side information)
    sign: jax.Array     # sign(g)
    tau: jax.Array      # lower level index per entry
    s: int              # number of quantization levels

    def value(self, bits: jax.Array) -> jax.Array:
        """Reconstruct  ||g|| * sign(g) * (tau + bits) / s ."""
        return self.norm * self.sign * (self.tau + bits) / self.s


def qsgd(g: jax.Array, *, s: int) -> QsgdPosterior:
    """Q_s of Alistarh et al.: unbiased stochastic quantization to s levels."""
    norm = jnp.linalg.norm(g) + 1e-12
    r = jnp.abs(g) / norm * s            # in [0, s]
    tau = jnp.clip(jnp.floor(r), 0, s - 1)
    q = clip01(r - tau)
    return QsgdPosterior(q=q, norm=norm, sign=jnp.sign(g), tau=tau, s=s)


def qsgd_sample(key: jax.Array, post: QsgdPosterior) -> jax.Array:
    """Draw the native (non-MRC) Q_s sample -- used to validate unbiasedness."""
    bits = jax.random.bernoulli(key, post.q).astype(jnp.float32)
    return post.value(bits)


# ---------------------------------------------------------------------------
# Deterministic baseline compressors
# ---------------------------------------------------------------------------


def sign_compress(g: jax.Array) -> jax.Array:
    """1-bit SignSGD with magnitude scaling (mean-|g| scale, as in MemSGD).

    The sign is *binary* (zero maps to +1), not ternary ``jnp.sign``: the
    booked rate is 1 bit/param + one scale, and only a two-valued sign is
    representable at that rate (cf. the repro.wire sign codec).
    """
    scale = jnp.mean(jnp.abs(g))
    return scale * jnp.where(g >= 0, 1.0, -1.0)


def topk_compress(g: jax.Array, k: int) -> jax.Array:
    """Keep the k largest-magnitude entries (biased, contractive)."""
    d = g.shape[0]
    k = min(k, d)
    _, idx = jax.lax.top_k(jnp.abs(g), k)
    out = jnp.zeros_like(g)
    return out.at[idx].set(g[idx])


def randk_compress(key: jax.Array, g: jax.Array, k: int) -> jax.Array:
    """Keep k uniformly random entries, rescaled by d/k (unbiased)."""
    d = g.shape[0]
    idx = jax.random.choice(key, d, (k,), replace=False)
    out = jnp.zeros_like(g)
    return out.at[idx].set(g[idx] * (d / k))


# Bit costs per parameter for the baseline compressors (32-bit floats, index
# cost ceil(log2 d) for sparse methods). Used by core.bitmeter.
FLOAT_BITS = 32


def sign_bits(d: int) -> float:
    return float(d) + FLOAT_BITS  # 1 bit/param + one scale


def dense_bits(d: int) -> float:
    return float(d) * FLOAT_BITS


def topk_bits(d: int, k: int) -> float:
    import math
    return k * (FLOAT_BITS + math.ceil(math.log2(max(d, 2))))
