"""Sharding rules: logical param/activation axes -> mesh axes.

Scheme (MaxText-style):
* ``model`` axis: attention heads (flattened q/k/v/o output dim), FFN hidden,
  experts, vocab.
* ``data`` axis (+ ``pod``): batch; additionally the *stacked-layer* dim of
  scanned parameters (FSDP/ZeRO-3 style -- each scan step all-gathers one
  layer's weights, which is exactly the per-layer FSDP prefetch pattern).
* decode KV caches: batch on ``data``, merged kv-feature dim on ``model``.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def has_axis(name: str) -> bool:
    return _MESH is not None and name in _MESH.axis_names


def axis_size(name: str) -> int:
    if _MESH is None or name not in _MESH.axis_names:
        return 1
    return _MESH.shape[name]


def batch_axes():
    """Mesh axes the global batch is split over."""
    if has_axis("pod"):
        return ("pod", "data")
    return "data"


def _axis_prod(entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        n *= axis_size(a)
    return n


def sanitize(shape, spec: P) -> P:
    """Drop spec entries whose mesh axes do not divide the dim (e.g. the
    batch axis of the batch-1 long-context shape)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    fixed = [e if (e is None or dim % _axis_prod(e) == 0) else None
             for dim, e in zip(shape, entries)]
    return P(*fixed)


def constraint(x, spec: P):
    """with_sharding_constraint if a mesh is active, else identity."""
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, sanitize(x.shape, spec)))


# ---------------------------------------------------------------------------
# Param specs.  Leaves are annotated through naming conventions in
# transformer.param_specs (built alongside init); helper specs here.
# ---------------------------------------------------------------------------

def spec_embed() -> P:       # (vocab, d)
    return P("model", None)


def spec_head() -> P:        # (d, vocab)
    return P(None, "model")


def spec_stacked(inner: P) -> P:
    """Stacked-layer leading dim -> FSDP ('data') sharding."""
    return P("data", *inner)


def sharding_for(spec: P) -> Optional[NamedSharding]:
    if _MESH is None:
        return None
    return NamedSharding(_MESH, spec)
