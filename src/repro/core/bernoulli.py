"""Bernoulli-distribution utilities shared across the BiCompFL stack.

Everything operates on *parameter* vectors/matrices theta in [0, 1]; a model
of dimension d is a vector of d independent Bernoulli parameters (FedPM-style
probabilistic masks), or -- in the CFL path -- the success probabilities
produced by a stochastic quantizer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Numerical floor keeping log-ratios finite. The paper's Theorem 1 assumes
# p_j > zeta; operationally we clip all Bernoulli parameters to [EPS, 1-EPS].
EPS = 1e-6


def clip01(x: jax.Array) -> jax.Array:
    """Clip a Bernoulli parameter into the open interval (0, 1)."""
    return jnp.clip(x, EPS, 1.0 - EPS)


def bern_kl(q: jax.Array, p: jax.Array) -> jax.Array:
    """Elementwise d_KL(q || p) between Bernoulli parameters (natural log)."""
    q = clip01(q)
    p = clip01(p)
    return q * jnp.log(q / p) + (1.0 - q) * jnp.log((1.0 - q) / (1.0 - p))


def bern_kl_bits(q: jax.Array, p: jax.Array) -> jax.Array:
    """Elementwise KL in bits (the unit the MRC cost model uses)."""
    return bern_kl(q, p) / jnp.log(2.0)


def log_ratio_coeffs(q: jax.Array, p: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Coefficients (a, b) such that, for a candidate x in {0,1}^d,

        log (Q(x)/P(x)) = sum_e  x_e * a_e + b_e

    with a = log(q/p) - log((1-q)/(1-p)) and b = log((1-q)/(1-p)).
    This turns MRC importance-weight evaluation into a matvec X @ a + sum(b),
    which is what the Pallas kernel accelerates on the MXU.
    """
    q = clip01(q)
    p = clip01(p)
    llr1 = jnp.log(q) - jnp.log(p)
    llr0 = jnp.log1p(-q) - jnp.log1p(-p)
    return llr1 - llr0, llr0


def sigmoid(x: jax.Array) -> jax.Array:
    return jax.nn.sigmoid(x)


def inv_sigmoid(theta: jax.Array) -> jax.Array:
    """Map primal Bernoulli parameters to dual-space scores (mirror map)."""
    theta = clip01(theta)
    return jnp.log(theta) - jnp.log1p(-theta)
