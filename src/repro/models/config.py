"""Architecture configuration for the assigned model zoo.

Each assigned architecture gets a module in ``repro.configs`` exporting an
``ArchConfig`` built from this dataclass; ``reduced()`` derives the smoke-test
variant (2 layers, d_model <= 512, <= 4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128

    # --- attention variants -------------------------------------------------
    qk_norm: bool = False
    causal: bool = True             # False => encoder-only (no decode shapes)
    sliding_window: int = 0         # >0 => SWA (enables long_500k for dense)
    long_context_window: int = 0    # >0 => long_500k runs an SWA variant
    rope_kind: str = "rope"         # rope | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    rope_theta: float = 1e4

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # expert hidden size (0 => d_ff)
    shared_experts: int = 0         # always-on shared expert MLPs
    first_dense_layers: int = 0     # leading layers with dense FFN (DeepSeek/K2)
    moe_every: int = 1              # MoE each k-th layer (Llama4: 2 = 1:1 interleave)
    capacity_factor: float = 1.25

    # --- mixer kind / hybrid layout ------------------------------------------
    block_kind: str = "attn"        # attn | rwkv6 | jamba
    attn_period: int = 0            # jamba: attn at index attn_offset of each unit
    attn_offset: int = 4
    moe_period: int = 0             # jamba: MoE at odd indices of each unit

    # --- mamba (jamba) ---------------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- input modality --------------------------------------------------------
    embed_inputs: bool = True       # False => inputs are frame embeddings (audio)
    vlm_image_tokens: int = 0       # >0 => accepts (B, n, d) image embeds (vlm)

    dtype: str = "bfloat16"
    kv_cache_quant: bool = False    # int8 KV cache + per-(pos, head) scales
                                    # (beyond-paper: halves decode cache HBM)
    remat: bool = True
    remat_policy: str = "full"      # full | dots (save matmul outputs,
                                    # recompute only elementwise in backward)
    scan_chunk: int = 0             # >0: chunked closed-form recurrence
                                    # (RWKV6 time-mix) instead of per-token scan
    source: str = ""                # citation

    # ------------------------------------------------------------------
    @property
    def moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def supports_decode(self) -> bool:
        return self.causal  # encoder-only archs have no decode step

    @property
    def supports_long_context(self) -> bool:
        """long_500k needs sub-quadratic attention at decode."""
        if self.block_kind in ("rwkv6", "jamba"):
            return True
        return self.sliding_window > 0 or self.long_context_window > 0

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family, tiny dims (CPU-runnable)."""
        changes = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 256),
            vocab=min(self.vocab, 512),
            head_dim=32,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            dtype="float32",
            remat=False,
        )
        if self.moe:
            changes.update(n_experts=4, top_k=min(self.top_k, 2),
                           moe_d_ff=min(self.moe_d_ff or self.d_ff, 128),
                           shared_experts=min(self.shared_experts, 1))
        if self.block_kind == "jamba":
            changes.update(n_layers=8)  # one full jamba unit
        if self.vlm_image_tokens:
            changes.update(vlm_image_tokens=16)
        if self.rope_kind == "mrope":
            changes.update(mrope_sections=(4, 6, 6))
        return dataclasses.replace(self, **changes)

    def params_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D roofline term)."""
        d, hd = self.d_model, self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        dense_ffn = 3 * d * self.d_ff
        moe_ff = self.moe_d_ff or self.d_ff
        moe_ffn = self.n_experts * 3 * d * moe_ff + d * self.n_experts \
            + self.shared_experts * 3 * d * moe_ff
        mamba_inner = self.d_inner
        mamba = (d * 2 * mamba_inner + mamba_inner * self.mamba_d_conv
                 + mamba_inner * (2 * self.mamba_d_state + 2) + mamba_inner * d)
        rwkv = 4 * d * d + d * d + 2 * d * self.d_ff  # r,k,v,g,o + channel-mix

        total = 0
        for i in range(self.n_layers):
            kind, ffn = self.layer_plan(i)
            if kind == "attn":
                total += attn
            elif kind == "mamba":
                total += mamba
            elif kind == "rwkv6":
                total += rwkv
            if ffn == "dense":
                total += dense_ffn
            elif ffn == "moe":
                total += moe_ffn
        total += self.vocab * d  # embed
        total += d * self.vocab  # head
        return total

    def active_params_count(self) -> int:
        """Active parameters per token (MoE: only top-k + shared experts)."""
        if not self.moe:
            return self.params_count()
        d = self.d_model
        moe_ff = self.moe_d_ff or self.d_ff
        full_moe = self.n_experts * 3 * d * moe_ff
        active_moe = self.top_k * 3 * d * moe_ff
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.layer_plan(i)[1] == "moe")
        return self.params_count() - n_moe_layers * (full_moe - active_moe)

    def layer_plan(self, i: int):
        """(mixer_kind, ffn_kind) for layer i."""
        if self.block_kind == "rwkv6":
            return "rwkv6", "rwkv_ffn"
        if self.block_kind == "jamba":
            pos = i % self.attn_period if self.attn_period else i
            mixer = "attn" if (self.attn_period and pos == self.attn_offset) else "mamba"
            ffn = "moe" if (self.moe_period and pos % self.moe_period == 1) else "dense"
            return mixer, ffn
        ffn = "dense"
        if self.moe and i >= self.first_dense_layers \
                and (i - self.first_dense_layers) % self.moe_every == 0:
            ffn = "moe"
        return "attn", ffn
