"""Checkpointing: save/restore arbitrary pytrees (params + optimizer state).

Self-contained binary format (no external deps): a JSON header describing
the tree structure + dtype/shape per leaf, followed by raw little-endian
leaf buffers.  Restore rebuilds the exact pytree (dict / list / tuple
nesting) and can re-shard onto a mesh via device_put.

Crash safety: ``save`` writes to a unique temp file, fsyncs it, and
atomically renames it over the target (a crash mid-save can never shadow
a good checkpoint with a torn one), and ``latest_step`` / ``latest``
*validate* candidates -- magic, parseable header, complete payload --
warning on and skipping corrupt or partially-written files instead of
choosing them.

Two addressing modes:

* single file -- ``save(path, tree, step=)`` / ``restore(path, like)`` /
  ``load(path)``: one checkpoint, overwritten in place (atomically);
* step directory -- ``save_step(dir, tree, step)`` / ``latest(dir)``:
  one ``ckpt_<step>.repro`` file per step, so an interrupted run resumes
  from the newest *valid* step (the FL engine's ``resume_from=``).

``load`` needs no reference tree: v2 headers carry a JSON ``structure``
descriptor (nested dicts/lists/tuples with leaf indices) alongside the
legacy ``treedef`` string, so a resuming process can rebuild the saved
state without reconstructing its shape first.  Scalars saved from Python
floats/ints come back as 0-d numpy arrays (bit-exact round-trip).
"""
from __future__ import annotations

import json
import os
import struct
import warnings
from typing import Any, Optional, Tuple

import jax
import numpy as np

MAGIC = b"REPROCKPT1"
_STEP_FMT = "ckpt_{step:08d}.repro"


class CheckpointError(AssertionError):
    """A checkpoint file is torn or structurally invalid (loud by design,
    like :class:`repro.core.bitmeter.ReconcileError`)."""


# ---------------------------------------------------------------------------
# Structure descriptor: JSON-serializable nesting with leaves as indices.
# ---------------------------------------------------------------------------


def _describe(tree, counter) -> Any:
    if isinstance(tree, dict):
        # jax.tree.leaves flattens dicts in sorted-key order; the
        # descriptor must hand out leaf indices in the same order or a
        # dict with non-alphabetical insertion order rebuilds scrambled.
        return {"kind": "dict",
                "items": [[k, _describe(v, counter)]
                          for k, v in sorted(tree.items())]}
    if isinstance(tree, (list, tuple)):
        kind = "list" if isinstance(tree, list) else "tuple"
        return {"kind": kind,
                "items": [_describe(v, counter) for v in tree]}
    if tree is None:
        return {"kind": "none"}
    idx = counter[0]
    counter[0] += 1
    return {"kind": "leaf", "index": idx}


def _rebuild(desc, leaves) -> Any:
    kind = desc["kind"]
    if kind == "dict":
        return {k: _rebuild(v, leaves) for k, v in desc["items"]}
    if kind == "list":
        return [_rebuild(v, leaves) for v in desc["items"]]
    if kind == "tuple":
        return tuple(_rebuild(v, leaves) for v in desc["items"])
    if kind == "none":
        return None
    return leaves[desc["index"]]


# ---------------------------------------------------------------------------
# Save / restore.
# ---------------------------------------------------------------------------


def save(path: str, tree, *, step: Optional[int] = None) -> None:
    leaves = jax.tree.leaves(tree)
    leaves = [np.asarray(l) for l in leaves]
    treedef = jax.tree.structure(tree)
    counter = [0]
    structure = _describe(tree, counter)
    header = {
        "treedef": str(treedef),
        "structure": structure if counter[0] == len(leaves) else None,
        "step": step,
        "leaves": [{"dtype": str(l.dtype), "shape": list(l.shape)}
                   for l in leaves],
    }
    hdr = json.dumps(header).encode()
    # Unique temp name (pid) so two writers cannot tear each other's temp;
    # fsync file + directory so the rename is durable before it is visible.
    tmp = f"{path}.tmp.{os.getpid()}"
    dirname = os.path.dirname(os.path.abspath(path))
    os.makedirs(dirname, exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(hdr)))
        f.write(hdr)
        for l in leaves:
            f.write(np.ascontiguousarray(l).tobytes())
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    try:
        dfd = os.open(dirname, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:  # directory fsync is best-effort (not all FSes allow it)
        pass


def _read_header(f) -> dict:
    magic = f.read(len(MAGIC))
    if magic != MAGIC:
        raise CheckpointError("not a repro checkpoint (bad magic)")
    raw = f.read(8)
    if len(raw) != 8:
        raise CheckpointError("truncated header length")
    (hlen,) = struct.unpack("<Q", raw)
    hdr = f.read(hlen)
    if len(hdr) != hlen:
        raise CheckpointError("truncated header")
    try:
        header = json.loads(hdr)
    except ValueError as e:
        raise CheckpointError(f"unparseable header: {e}") from e
    if not isinstance(header, dict) or "leaves" not in header:
        raise CheckpointError("header missing leaf table")
    return header


def _payload_bytes(header) -> int:
    total = 0
    for meta in header["leaves"]:
        dt = np.dtype(meta["dtype"])
        n = int(np.prod(meta["shape"])) if meta["shape"] else 1
        total += n * dt.itemsize
    return total


def _read_leaves(f, header):
    out = []
    for meta in header["leaves"]:
        dt = np.dtype(meta["dtype"])
        n = int(np.prod(meta["shape"])) if meta["shape"] else 1
        buf = f.read(n * dt.itemsize)
        if len(buf) != n * dt.itemsize:
            raise CheckpointError("truncated leaf payload")
        out.append(np.frombuffer(buf, dt).reshape(meta["shape"]))
    return out


def restore(path: str, like, *, mesh=None, specs=None):
    """Restore into the structure of ``like`` (a pytree of arrays/SDS)."""
    with open(path, "rb") as f:
        header = _read_header(f)
        out_leaves = _read_leaves(f, header)
    treedef = jax.tree.structure(like)
    ref_leaves = jax.tree.leaves(like)
    if len(ref_leaves) != len(out_leaves):
        raise CheckpointError(
            f"checkpoint has {len(out_leaves)} leaves, reference tree "
            f"{len(ref_leaves)}")
    arrs = []
    for ref, val in zip(ref_leaves, out_leaves):
        if tuple(ref.shape) != tuple(val.shape):
            raise CheckpointError(
                f"leaf shape mismatch: checkpoint {tuple(val.shape)} vs "
                f"reference {tuple(ref.shape)}")
        arrs.append(val)
    tree = jax.tree.unflatten(treedef, arrs)
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        shardings = jax.tree.map(
            lambda sp: NamedSharding(mesh, sp), specs,
            is_leaf=lambda t: isinstance(t, P))
        tree = jax.device_put(tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree


def load(path: str) -> Tuple[Any, Optional[int]]:
    """Load ``(tree, step)`` with no reference tree (self-describing v2).

    Leaves come back as numpy arrays (0-d for saved Python scalars);
    callers convert to device arrays where needed.  Raises
    :class:`CheckpointError` on files saved without a structure
    descriptor (pre-v2) or on any corruption.
    """
    with open(path, "rb") as f:
        header = _read_header(f)
        if header.get("structure") is None:
            raise CheckpointError(
                f"{path} has no structure descriptor; use restore(path, "
                "like) with a reference tree")
        leaves = _read_leaves(f, header)
    return _rebuild(header["structure"], leaves), header.get("step")


# ---------------------------------------------------------------------------
# Validation + latest-step discovery (skip torn files, loudly).
# ---------------------------------------------------------------------------


def validate(path: str) -> Tuple[bool, Optional[int], str]:
    """Cheap structural check: ``(ok, step, reason)``.

    Verifies magic, header parse, and that the file carries the complete
    leaf payload the header promises -- the failure modes of a crash
    mid-write (should never happen with the atomic ``save``, but a prior
    non-atomic writer or a copied partial file still must not be chosen).
    """
    try:
        size = os.path.getsize(path)
        with open(path, "rb") as f:
            header = _read_header(f)
            body_start = f.tell()
        expected = body_start + _payload_bytes(header)
        if size < expected:
            return False, header.get("step"), (
                f"truncated payload ({size} bytes, header promises "
                f"{expected})")
        return True, header.get("step"), ""
    except (OSError, CheckpointError, ValueError) as e:
        return False, None, str(e)


def latest_step(path: str) -> Optional[int]:
    """Step recorded in ``path``, or None if absent or corrupt (warns)."""
    if not os.path.exists(path):
        return None
    ok, step, reason = validate(path)
    if not ok:
        warnings.warn(f"skipping corrupt checkpoint {path}: {reason}",
                      RuntimeWarning, stacklevel=2)
        return None
    return step


def step_path(directory: str, step: int) -> str:
    return os.path.join(directory, _STEP_FMT.format(step=int(step)))


def save_step(directory: str, tree, step: int) -> str:
    """Save one per-step checkpoint file under ``directory``."""
    path = step_path(directory, step)
    save(path, tree, step=int(step))
    return path


def latest(directory: str) -> Tuple[Optional[str], Optional[int]]:
    """Newest *valid* per-step checkpoint in ``directory``.

    Scans ``ckpt_*.repro`` files newest-first, warns on and skips any
    corrupt/partial candidate, and returns ``(path, step)`` of the first
    valid one -- ``(None, None)`` when the directory holds none.
    """
    if not os.path.isdir(directory):
        return None, None
    names = sorted((n for n in os.listdir(directory)
                    if n.startswith("ckpt_") and n.endswith(".repro")),
                   reverse=True)
    for name in names:
        path = os.path.join(directory, name)
        ok, step, reason = validate(path)
        if not ok:
            warnings.warn(f"skipping corrupt checkpoint {path}: {reason}",
                          RuntimeWarning, stacklevel=2)
            continue
        if step is None:  # step files always record their step
            warnings.warn(f"skipping step-less checkpoint {path}",
                          RuntimeWarning, stacklevel=2)
            continue
        return path, int(step)
    return None, None
