"""Checkpointing: save/restore arbitrary pytrees (params + optimizer state).

Self-contained binary format (no external deps): a JSON header describing
the tree structure + dtype/shape per leaf, followed by raw little-endian
leaf buffers.  Restore rebuilds the exact pytree (dict / list / tuple /
NamedTuple nesting) and can re-shard onto a mesh via device_put.
"""
from __future__ import annotations

import json
import os
import struct
from typing import Any, Optional

import jax
import numpy as np

MAGIC = b"REPROCKPT1"


def _encode_tree(tree) -> Any:
    """Structure descriptor with leaves replaced by indices."""
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, str(treedef)


def save(path: str, tree, *, step: Optional[int] = None) -> None:
    leaves = jax.tree.leaves(tree)
    leaves = [np.asarray(l) for l in leaves]
    treedef = jax.tree.structure(tree)
    header = {
        "treedef": str(treedef),
        "step": step,
        "leaves": [{"dtype": str(l.dtype), "shape": list(l.shape)} for l in leaves],
    }
    hdr = json.dumps(header).encode()
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(hdr)))
        f.write(hdr)
        for l in leaves:
            f.write(np.ascontiguousarray(l).tobytes())
    os.replace(tmp, path)


def restore(path: str, like, *, mesh=None, specs=None):
    """Restore into the structure of ``like`` (a pytree of arrays/SDS)."""
    with open(path, "rb") as f:
        assert f.read(len(MAGIC)) == MAGIC, "not a repro checkpoint"
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen))
        out_leaves = []
        for meta in header["leaves"]:
            dt = np.dtype(meta["dtype"])
            n = int(np.prod(meta["shape"])) if meta["shape"] else 1
            buf = f.read(n * dt.itemsize)
            out_leaves.append(np.frombuffer(buf, dt).reshape(meta["shape"]))
    treedef = jax.tree.structure(like)
    ref_leaves = jax.tree.leaves(like)
    assert len(ref_leaves) == len(out_leaves), "checkpoint/tree leaf mismatch"
    arrs = []
    for ref, val in zip(ref_leaves, out_leaves):
        assert tuple(ref.shape) == tuple(val.shape), (ref.shape, val.shape)
        arrs.append(val)
    tree = jax.tree.unflatten(treedef, arrs)
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        shardings = jax.tree.map(
            lambda sp: NamedSharding(mesh, sp), specs,
            is_leaf=lambda t: isinstance(t, P))
        tree = jax.device_put(tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree


def latest_step(path: str) -> Optional[int]:
    if not os.path.exists(path):
        return None
    with open(path, "rb") as f:
        assert f.read(len(MAGIC)) == MAGIC
        (hlen,) = struct.unpack("<Q", f.read(8))
        return json.loads(f.read(hlen)).get("step")
