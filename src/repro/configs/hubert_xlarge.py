"""HuBERT X-Large: encoder-only audio backbone (frontend stubbed).  [arXiv:2106.07447]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", arch_type="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, head_dim=80,
    causal=False, embed_inputs=False,
    source="arXiv:2106.07447 (same arch as wav2vec2 XL)",
)
