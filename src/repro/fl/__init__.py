"""Federated-learning runtime: tasks, data, federator loops, baselines."""
from . import baselines, data, federator, nets, tasks  # noqa: F401
