"""Non-stochastic bi-directional compression baselines (paper Section 4).

All baselines share one skeleton: clients compute a local delta ("gradient"),
apply an uplink compressor (with error feedback where the original scheme
uses it), the federator aggregates + optionally compresses the downlink, and
bits are booked from what is actually transmitted.

Schemes (with the simplifications we make, cf. DESIGN.md):

* fedavg         : dense 32-bit both directions.
* memsgd         : Stich et al. 2018  -- sign + EF uplink, dense downlink.
* doublesqueeze  : Tang et al. 2019  -- sign + EF uplink AND downlink.
* neolithic      : Huang et al. 2022 -- as doublesqueeze with R=2 compression
                   passes per direction (2 bits/param effective).
* cser           : Xie et al. 2020   -- sign + EF uplink, dense downlink,
                   periodic error reset (period 50) adds an amortized sync.
* liec           : Cheng et al. 2024 -- bidirectional sign with immediate
                   local error compensation + periodic averaging (period 50).
* m3             : Gruntkowska et al. 2024 -- TopK(d/n) + EF uplink; downlink
                   sends each client a *disjoint* 1/n model slice (dense);
                   clients hold diverging model estimates.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.core.bitmeter import BitMeter
from repro.core.quantizers import (FLOAT_BITS, sign_compress, topk_bits,
                                   topk_compress)
from .data import Dataset


@dataclass
class BaselineConfig:
    scheme: str = "fedavg"
    rounds: int = 30
    server_lr: float = 1.0
    seed: int = 0
    eval_every: int = 1
    reset_period: int = 50   # CSER / LIEC periodic sync


def run_baseline(task, theta0: jax.Array, shards: Dataset, cfg: BaselineConfig) -> Dict[str, Any]:
    n = int(shards.x.shape[0])
    d = int(theta0.shape[0])
    base = jax.random.PRNGKey(cfg.seed)
    scheme = cfg.scheme.lower()
    meter = BitMeter(n_clients=n, d=d,
                     broadcast_downlink_shareable=(scheme != "m3"))

    theta = theta0                                   # server model
    theta_hat = jnp.tile(theta0[None], (n, 1))       # client estimates
    e_up = jnp.zeros((n, d))                         # client EF memories
    e_down = jnp.zeros((d,))                         # server EF memory
    k_m3 = max(d // n, 1)
    history: List[Dict[str, float]] = []

    def sign2(v):
        """Two-pass sign compression (Neolithic's repeated compression)."""
        c1 = sign_compress(v)
        c2 = sign_compress(v - c1)
        return c1 + c2

    for t in range(cfg.rounds):
        kt = jax.random.fold_in(base, t)
        train_keys = jax.random.split(jax.random.fold_in(kt, 1), n)
        deltas = jax.vmap(task.local_train)(theta_hat, shards.x, shards.y, train_keys)

        ul_bits = dl_bits = 0.0
        if scheme == "fedavg":
            agg = jnp.mean(deltas, axis=0)
            theta = theta - cfg.server_lr * agg
            theta_hat = jnp.tile(theta[None], (n, 1))
            ul_bits = n * d * FLOAT_BITS
            dl_bits = n * d * FLOAT_BITS
        elif scheme in ("memsgd", "cser"):
            c = jax.vmap(sign_compress)(deltas + e_up)
            e_up = deltas + e_up - c
            theta = theta - cfg.server_lr * jnp.mean(c, axis=0)
            theta_hat = jnp.tile(theta[None], (n, 1))
            ul_bits = n * (d + FLOAT_BITS)
            dl_bits = n * d * FLOAT_BITS
            if scheme == "cser" and (t + 1) % cfg.reset_period == 0:
                # error reset: flush residuals (dense sync, both directions)
                theta = theta - cfg.server_lr * jnp.mean(e_up, axis=0)
                e_up = jnp.zeros_like(e_up)
                theta_hat = jnp.tile(theta[None], (n, 1))
                ul_bits += n * d * FLOAT_BITS
                dl_bits += n * d * FLOAT_BITS
        elif scheme in ("doublesqueeze", "neolithic", "liec"):
            comp = sign2 if scheme == "neolithic" else sign_compress
            bits_per = 2.0 if scheme == "neolithic" else 1.0
            c = jax.vmap(comp)(deltas + e_up)
            e_up = deltas + e_up - c
            agg = jnp.mean(c, axis=0) + e_down
            c_s = comp(agg)
            e_down = agg - c_s
            theta = theta - cfg.server_lr * c_s
            theta_hat = theta_hat - cfg.server_lr * c_s[None, :]
            ul_bits = n * (bits_per * d + FLOAT_BITS * (2 if scheme == "neolithic" else 1))
            dl_bits = n * (bits_per * d + FLOAT_BITS * (2 if scheme == "neolithic" else 1))
            if scheme == "liec" and (t + 1) % cfg.reset_period == 0:
                # periodic exact averaging (immediate-compensation flush)
                theta = theta - cfg.server_lr * (jnp.mean(e_up, axis=0) + e_down)
                e_up = jnp.zeros_like(e_up)
                e_down = jnp.zeros_like(e_down)
                theta_hat = jnp.tile(theta[None], (n, 1))
                ul_bits += n * d * FLOAT_BITS
                dl_bits += n * d * FLOAT_BITS
        elif scheme == "m3":
            c = jax.vmap(lambda v: topk_compress(v, k_m3))(deltas + e_up)
            e_up = deltas + e_up - c
            theta = theta - cfg.server_lr * jnp.mean(c, axis=0)
            # downlink: disjoint dense slices, one per client
            new_hat = []
            for i in range(n):
                lo = i * k_m3
                hi = d if i == n - 1 else min((i + 1) * k_m3, d)
                sl = theta_hat[i].at[lo:hi].set(theta[lo:hi])
                new_hat.append(sl)
            theta_hat = jnp.stack(new_hat)
            ul_bits = n * topk_bits(d, k_m3)
            dl_bits = n * (d / n) * FLOAT_BITS
        else:
            raise ValueError(scheme)

        meter.add_round(ul_bits, dl_bits)
        if (t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1:
            acc = task.evaluate(theta)
            history.append({"round": t + 1, "acc": float(acc),
                            "cum_bits": meter.total_bits})

    return {"history": history, "meter": meter.summary(), "theta": theta,
            "final_acc": history[-1]["acc"] if history else float("nan"),
            "max_acc": max(h["acc"] for h in history) if history else float("nan")}


ALL_BASELINES = ("fedavg", "memsgd", "doublesqueeze", "neolithic", "cser", "liec", "m3")
