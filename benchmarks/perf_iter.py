"""§Perf hillclimb driver: lower one (arch, shape) with config/mb
overrides and print the roofline delta vs. the recorded baseline.

    PYTHONPATH=src python -m benchmarks.perf_iter --arch rwkv6-1.6b \
        --shape train_4k --set scan_chunk=64 --mb 4
"""
import argparse
import json

from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS first)


def parse_overrides(items):
    out = {}
    for it in items or ():
        k, v = it.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig overrides, e.g. scan_chunk=64")
    ap.add_argument("--mb", type=int, default=None, help="microbatches")
    ap.add_argument("--kv-chunk", type=int, default=1024)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    res = dryrun.run_combo(args.arch, args.shape, multi_pod=args.multi_pod,
                           kv_chunk=args.kv_chunk,
                           overrides=parse_overrides(args.set),
                           microbatches=args.mb)
    rl = res.get("roofline", {})
    print(json.dumps({k: res[k] for k in
                      ("arch", "shape", "status", "compile_s") if k in res}))
    if rl:
        print(f"compute_s    {rl['compute_s']:.4f}")
        print(f"memory_s     {rl['memory_s']:.4f}")
        print(f"collective_s {rl['collective_s']:.4f}")
        print(f"dominant     {rl['dominant']}   bound {rl['bound_s']:.4f}")
        print(f"flops/dev {rl['flops_per_dev']:.3e}  "
              f"hbm/dev {rl['hbm_bytes_per_dev']:.3e}  "
              f"coll/dev {rl['coll_bytes_per_dev']:.3e}")
        print("collectives:", res["collectives"])
        print("memory:", {k: v for k, v in res["memory"].items()})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, default=str)


if __name__ == "__main__":
    main()
