"""Sharded trainer: pjit train_step with microbatch gradient accumulation.

``make_train_step(model, ...)`` builds the pure step function; ``Trainer``
wires it to a mesh with explicit parameter/optimizer/batch shardings.  The
same step function is what the multi-pod dry-run lowers.

Optimizer policy: Adam for models below ``ADAFACTOR_THRESHOLD`` parameters,
factored second-moment (adafactor-like) above -- f32 Adam moments for a
trillion-parameter MoE would not fit a v5e pod's HBM.

BiCompFL-at-scale (``grad_compression="stochastic_sign"``): every
data-parallel shard plays the role of a paper "client": its microbatch
gradient is stochastically sign-quantized (Q_s with K = mean |g|) and the
*sampled signs* are what the cross-shard aggregation averages -- the paper's
uplink structure mapped onto the mesh's gradient all-reduce.  The shared
prior (Ber(1/2)) and shared randomness (a per-step folded key) follow
BICOMPFL-GR-CFL (paper Section 4).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import optim
from repro.models import sharding, transformer as T
from repro.models.config import ArchConfig

ADAFACTOR_THRESHOLD = 100e9


def choose_optimizer(cfg: ArchConfig, lr: float = 1e-4) -> Tuple[str, optim.Optimizer]:
    if cfg.params_count() > ADAFACTOR_THRESHOLD:
        return "adafactor", optim.adafactor_like(lr)
    return "adam", optim.adam(lr)


def _spec_entries(spec: P, ndim: int):
    return list(spec) + [None] * (ndim - len(spec))


def opt_state_specs(opt_name: str, params_sds, param_specs):
    """PartitionSpec tree matching the optimizer state structure."""
    if opt_name == "adam":
        return optim.AdamState(mu=param_specs, nu=param_specs, step=P())
    if opt_name in ("sgd",):
        return ()
    if opt_name == "momentum":
        return param_specs
    if opt_name == "adafactor":
        flat_sds, tdef = jax.tree.flatten(params_sds)
        flat_specs = jax.tree.leaves(param_specs,
                                     is_leaf=lambda t: isinstance(t, P))
        out = []
        for sds, spec in zip(flat_sds, flat_specs):
            ent = _spec_entries(spec, sds.ndim)
            if sds.ndim >= 2:
                out.append((P(*ent[:-1]), P(*(ent[:-2] + ent[-1:]))))
            else:
                out.append(P(*ent))
        return jax.tree.unflatten(tdef, out)
    raise ValueError(opt_name)


def batch_specs(cfg: ArchConfig, batch_tree) -> Dict[str, P]:
    b = sharding.batch_axes()
    out = {}
    for name, leaf in batch_tree.items():
        out[name] = P(b, *([None] * (leaf.ndim - 1)))
    return out


# ---------------------------------------------------------------------------
# The step function
# ---------------------------------------------------------------------------


def make_loss_fn(model: T.Model, *, kv_chunk: int = 1024) -> Callable:
    if model.cfg.causal:
        return functools.partial(T.lm_loss, model, kv_chunk=kv_chunk)
    return functools.partial(T.encoder_loss, model, kv_chunk=kv_chunk)


def _stochastic_sign_compress(g: jax.Array, key: jax.Array) -> jax.Array:
    """Paper Q_s: per-tensor stochastic sign with temperature K = mean |g|."""
    k_temp = jnp.mean(jnp.abs(g)) + 1e-12
    q = jax.nn.sigmoid(g / k_temp)
    bit = jax.random.bernoulli(key, q).astype(g.dtype)
    return (2.0 * bit - 1.0) * k_temp


def make_train_step(model: T.Model, opt: optim.Optimizer, *,
                    microbatches: int = 1, kv_chunk: int = 1024,
                    grad_compression: Optional[str] = None) -> Callable:
    """(params, opt_state, batch[, key]) -> (loss, params, opt_state)."""
    loss_fn = make_loss_fn(model, kv_chunk=kv_chunk)

    def step(params, opt_state, batch, key=None):
        def split_mb(x):
            return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

        mbatch = jax.tree.map(split_mb, batch)

        def mb_body(acc, mb):
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            acc_loss, acc_grads = acc
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_grads, grads)), ()

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss_sum, grads), _ = jax.lax.scan(mb_body, zero, mbatch)
        loss = loss_sum / microbatches
        grads = jax.tree.map(lambda g: g / microbatches, grads)

        if grad_compression == "stochastic_sign":
            leaves, tdef = jax.tree.flatten(grads)
            keys = jax.random.split(key, len(leaves))
            grads = jax.tree.unflatten(
                tdef, [_stochastic_sign_compress(g, k)
                       for g, k in zip(leaves, keys)])

        params, opt_state = opt.update(grads, params, opt_state)
        return loss, params, opt_state

    return step


# ---------------------------------------------------------------------------
# Trainer: binds mesh + shardings
# ---------------------------------------------------------------------------


class TrainSetup(NamedTuple):
    model: T.Model
    opt_name: str
    opt: optim.Optimizer
    param_specs: Any
    opt_specs: Any
    params_sds: Any
    opt_sds: Any
    step_fn: Callable


def build_setup(cfg: ArchConfig, *, lr: float = 1e-4, microbatches: int = 1,
                kv_chunk: int = 1024, fsdp: bool = True,
                grad_compression: Optional[str] = None) -> TrainSetup:
    """Everything needed to jit/lower a train step (no allocation)."""
    model = T.build(cfg)
    opt_name, opt = choose_optimizer(cfg, lr)

    params_sds, param_specs = T.abstract_init(model)
    if fsdp:
        param_specs = T.fsdp_specs(params_sds, param_specs)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    o_specs = opt_state_specs(opt_name, params_sds, param_specs)
    step_fn = make_train_step(model, opt, microbatches=microbatches,
                              kv_chunk=kv_chunk, grad_compression=grad_compression)
    return TrainSetup(model, opt_name, opt, param_specs, o_specs,
                      params_sds, opt_sds, step_fn)


def shardings_for(mesh: Mesh, specs):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda t: isinstance(t, P))


class Trainer:
    """Real-execution trainer (examples + integration tests)."""

    def __init__(self, cfg: ArchConfig, mesh: Optional[Mesh] = None, *,
                 lr: float = 1e-4, microbatches: int = 1, kv_chunk: int = 1024,
                 grad_compression: Optional[str] = None, seed: int = 0):
        self.mesh = mesh
        sharding.set_mesh(mesh)
        self.setup = build_setup(cfg, lr=lr, microbatches=microbatches,
                                 kv_chunk=kv_chunk,
                                 grad_compression=grad_compression)
        self.cfg = cfg
        key = jax.random.PRNGKey(seed)
        self.params, _ = T.init_params(self.setup.model, key)
        self.opt_state = self.setup.opt.init(self.params)
        self.key = jax.random.fold_in(key, 1)
        self._jit = jax.jit(self.setup.step_fn)

    def step(self, batch) -> float:
        self.key, k = jax.random.split(self.key)
        loss, self.params, self.opt_state = self._jit(
            self.params, self.opt_state, batch, k)
        return float(loss)


# ---------------------------------------------------------------------------
# CLI launcher:
#   PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
#       --steps 50 [--batch 4 --seq 128 --bicompfl --ckpt /tmp/ck.bin]
# Full (non-reduced) configs are for real TPU slices; on this container use
# --reduced (the dry-run covers the full configs without allocation).
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    import argparse
    import time

    import jax.numpy as jnp

    import repro.configs as configs
    from repro import checkpoint
    from repro.data import batches_for
    from repro.launch.mesh import make_host_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list(configs.ALIASES))
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-sized) variant")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--bicompfl", action="store_true",
                    help="BiCompFL stochastic-sign gradient compression")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"training {cfg.name}: {cfg.params_count()/1e6:.1f}M params")

    trainer = Trainer(cfg, mesh=make_host_mesh(), lr=args.lr,
                      microbatches=args.microbatches, kv_chunk=args.seq,
                      grad_compression="stochastic_sign" if args.bicompfl else None)
    t0 = time.time()
    losses = []
    for step_i, batch in enumerate(batches_for(cfg, args.batch, args.seq,
                                               n=args.steps)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        losses.append(trainer.step(batch))
        if step_i % args.log_every == 0 or step_i == args.steps - 1:
            tok_s = (step_i + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {step_i:5d}  loss {losses[-1]:8.4f}  "
                  f"({tok_s:,.0f} tok/s)", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, trainer.params, step=args.steps)
        print(f"saved {args.ckpt}")
    return 0 if (len(losses) < 2 or losses[-1] < losses[0]) else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
