"""Stochastic quantizers: unbiasedness + variance bound + the Lemma-1
contraction of C_mrc(Q_s(.)) checked empirically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import mrc, quantizers as Q
from repro.core.bernoulli import clip01

KEY = jax.random.PRNGKey(1)


class TestQsgd:
    def test_unbiased(self):
        """E[Q_s(x)] == x  (Alistarh et al. 2017)."""
        g = jax.random.normal(KEY, (64,))
        post = Q.qsgd(g, s=4)
        keys = jax.random.split(jax.random.fold_in(KEY, 1), 4000)
        samples = jax.vmap(lambda k: Q.qsgd_sample(k, post))(keys)
        err = float(jnp.max(jnp.abs(jnp.mean(samples, 0) - g)))
        assert err < 0.05 * float(jnp.linalg.norm(g)), err

    def test_variance_bound(self):
        """E||Q_s(x) - x||^2 <= min(d/s^2, sqrt(d)/s) ||x||^2."""
        d, s = 128, 16
        g = jax.random.normal(KEY, (d,))
        post = Q.qsgd(g, s=s)
        keys = jax.random.split(jax.random.fold_in(KEY, 2), 2000)
        samples = jax.vmap(lambda k: Q.qsgd_sample(k, post))(keys)
        var = float(jnp.mean(jnp.sum((samples - g) ** 2, -1)))
        bound = min(d / s ** 2, np.sqrt(d) / s) * float(jnp.sum(g ** 2))
        assert var <= bound * 1.1, (var, bound)

    @given(st.integers(2, 64))
    @settings(max_examples=20, deadline=None)
    def test_levels_hit(self, s):
        g = jax.random.normal(KEY, (32,))
        post = Q.qsgd(g, s=s)
        bits = jnp.ones_like(post.q)
        vals = np.abs(np.asarray(post.value(bits))) / float(post.norm) * s
        assert np.all(vals <= s + 1e-4)


class TestStochasticSign:
    def test_posterior_monotone(self):
        g = jnp.array([-3.0, -0.1, 0.0, 0.1, 3.0])
        q = np.asarray(Q.stochastic_sign(g, temperature=1.0).q)
        assert np.all(np.diff(q) >= 0)
        assert abs(q[2] - 0.5) < 1e-6

    def test_value_mapping(self):
        post = Q.stochastic_sign(jnp.zeros((4,)))
        np.testing.assert_allclose(np.asarray(post.value(jnp.ones(4))), 1.0)
        np.testing.assert_allclose(np.asarray(post.value(jnp.zeros(4))), -1.0)


class TestBaselines:
    def test_topk_keeps_largest(self):
        g = jnp.array([0.1, -5.0, 0.3, 2.0])
        out = np.asarray(Q.topk_compress(g, 2))
        assert out[1] == -5.0 and out[3] == 2.0 and out[0] == 0.0

    def test_randk_unbiased(self):
        g = jax.random.normal(KEY, (32,))
        keys = jax.random.split(KEY, 3000)
        outs = jax.vmap(lambda k: Q.randk_compress(k, g, 8))(keys)
        err = float(jnp.max(jnp.abs(jnp.mean(outs, 0) - g)))
        assert err < 0.25 * float(jnp.max(jnp.abs(g))), err

    def test_sign_compress_scale(self):
        g = jnp.array([1.0, -2.0, 3.0])
        out = np.asarray(Q.sign_compress(g))
        np.testing.assert_allclose(np.abs(out), 2.0, rtol=1e-6)


class TestLemma1Contraction:
    """Empirical check of Lemma 1:  E||C_mrc(Q_s(x)) - x||^2 <= (1-d)||x||^2
    with a strictly positive d for s >= sqrt(2 d_model) and adequate n_IS."""

    @pytest.mark.parametrize("n_is", [16, 256])
    def test_contraction(self, n_is):
        d = 64
        s = int(np.ceil(np.sqrt(2 * d))) + 2
        g = jax.random.normal(KEY, (d,))
        post = Q.qsgd(g, s=s)
        prior = jnp.full((1, d), 0.5)

        def one(key):
            _, bits = mrc.transmit_fixed(
                key, jax.random.fold_in(key, 1), post.q.reshape(1, d),
                prior, n_is=n_is, n_samples=1)
            return post.value(bits.reshape(d))

        keys = jax.random.split(jax.random.fold_in(KEY, n_is), 300)
        recon = jax.vmap(one)(keys)
        mse = float(jnp.mean(jnp.sum((recon - g) ** 2, -1)))
        norm2 = float(jnp.sum(g ** 2))
        assert mse < norm2, f"no contraction: {mse} >= {norm2}"

    def test_contraction_improves_with_nis(self):
        d = 64
        s = int(np.ceil(np.sqrt(2 * d))) + 2
        g = jax.random.normal(jax.random.fold_in(KEY, 5), (d,))
        post = Q.qsgd(g, s=s)
        prior = jnp.full((1, d), 0.5)
        mses = []
        for n_is in (4, 512):
            def one(key):
                _, bits = mrc.transmit_fixed(
                    key, jax.random.fold_in(key, 1), post.q.reshape(1, d),
                    prior, n_is=n_is, n_samples=1)
                return post.value(bits.reshape(d))
            keys = jax.random.split(jax.random.fold_in(KEY, 100 + n_is), 200)
            recon = jax.vmap(one)(keys)
            mses.append(float(jnp.mean(jnp.sum((recon - g) ** 2, -1))))
        assert mses[1] < mses[0], mses
