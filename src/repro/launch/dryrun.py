import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

DOC = """Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The container has one CPU device; the two lines above (before ANY jax
import) give XLA 512 host placeholder devices so ``make_production_mesh``
can build the production meshes.  Nothing is allocated: inputs, params,
optimizer state and caches are ShapeDtypeStructs.

Per combination this prints/collects:
  * memory_analysis()  -- per-device argument/temp bytes (does it fit HBM?)
  * cost_analysis()    -- per-device FLOPs + bytes accessed
  * the collective schedule parsed from the optimized HLO
  * the three roofline terms (see launch/roofline.py)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""

import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.models import sharding, transformer as T
from repro.launch import roofline as RL
from repro.launch import train as train_lib
from repro.launch.mesh import make_production_mesh


# Microbatch counts keeping per-device activation checkpoints << HBM.
def default_microbatches(cfg, global_batch: int, data_total: int) -> int:
    """Gradient-accumulation depth: ~1 sample/device/microbatch for large
    models (activation checkpoints dominate), more for small ones."""
    b_local = max(1, global_batch // max(data_total, 1))
    target_local = 1 if cfg.params_count() > 20e9 else min(4, b_local)
    return max(1, b_local // target_local)


def _shardings(mesh, specs_tree, sds_tree):
    """NamedShardings with per-leaf sanitation against actual dims."""
    flat_specs, sdef = jax.tree.flatten(
        specs_tree, is_leaf=lambda t: isinstance(t, P))
    flat_sds = jax.tree.leaves(sds_tree)
    out = []
    for spec, sds in zip(flat_specs, flat_sds):
        out.append(NamedSharding(mesh, sharding.sanitize(sds.shape, spec)))
    return jax.tree.unflatten(sdef, out)


def lower_combo(arch: str, shape: str, mesh, *, kv_chunk: int = 1024,
                donate: bool = True, overrides: Optional[Dict] = None,
                microbatches: Optional[int] = None):
    """Returns (lowered, compiled, meta) for one (arch, shape, mesh)."""
    import dataclasses
    cfg = configs.for_shape(configs.get(arch), shape)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    info = configs.SHAPES[shape]
    sharding.set_mesh(mesh)
    model = T.build(cfg)
    batch_sds = configs.input_specs(cfg, shape)
    kind = info["kind"]

    repl = NamedSharding(mesh, P())
    if kind == "train":
        data_total = mesh.devices.size // mesh.shape["model"]
        mb = microbatches or default_microbatches(cfg, info["batch"], data_total)
        setup = train_lib.build_setup(cfg, microbatches=mb, kv_chunk=kv_chunk)
        p_shard = _shardings(mesh, setup.param_specs, setup.params_sds)
        o_shard = _shardings(mesh, setup.opt_specs, setup.opt_sds)
        b_specs = train_lib.batch_specs(cfg, batch_sds)
        b_shard = _shardings(mesh, b_specs, batch_sds)
        fn = jax.jit(
            setup.step_fn,
            in_shardings=(p_shard, o_shard, b_shard, repl),
            out_shardings=(None, p_shard, o_shard),
            donate_argnums=(0, 1) if donate else (),
        )
        key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
        lowered = fn.lower(setup.params_sds, setup.opt_sds, batch_sds, key_sds)
        meta = {"kind": "train", "microbatches": mb,
                "optimizer": setup.opt_name}
    elif kind == "prefill":
        params_sds, param_specs = T.abstract_init(model)
        param_specs = T.fsdp_specs(params_sds, param_specs)
        p_shard = _shardings(mesh, param_specs, params_sds)
        b_specs = train_lib.batch_specs(cfg, batch_sds)
        b_shard = _shardings(mesh, b_specs, batch_sds)
        fn = jax.jit(
            lambda params, batch: T.prefill_step(model, params, batch,
                                                 kv_chunk=kv_chunk),
            in_shardings=(p_shard, b_shard))
        lowered = fn.lower(params_sds, batch_sds)
        meta = {"kind": "prefill"}
    else:  # decode
        params_sds, param_specs = T.abstract_init(model)
        # decode params: keep weights sharded over model only (no ZeRO
        # all-gathers on the latency path); embed/head stay 2-D sharded.
        p_shard = _shardings(mesh, param_specs, params_sds)
        b = info["batch"]
        cache_sds = jax.eval_shape(
            lambda: T.init_cache(model, b, info["seq"]))
        c_specs = T.cache_specs(model, batch=b)
        c_shard = _shardings(mesh, c_specs, cache_sds)
        tok_sds = batch_sds["tokens"]
        tok_shard = NamedSharding(
            mesh, sharding.sanitize(tok_sds.shape,
                                    P(sharding.batch_axes(), None)))
        fn = jax.jit(
            lambda params, cache, tokens, pos: T.serve_step(
                model, params, cache, tokens, pos),
            in_shardings=(p_shard, c_shard, tok_shard, repl),
            out_shardings=(None, c_shard),
            donate_argnums=(1,) if donate else (),
        )
        lowered = fn.lower(params_sds, cache_sds, tok_sds,
                           jax.ShapeDtypeStruct((), jnp.int32))
        meta = {"kind": "decode"}

    compiled = lowered.compile()
    return lowered, compiled, meta


def run_combo(arch: str, shape: str, *, multi_pod: bool = False,
              kv_chunk: int = 1024, verbose: bool = True,
              overrides: Optional[Dict] = None,
              microbatches: Optional[int] = None) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = configs.get(arch)
    skip = configs.shape_supported(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skip", "reason": skip}
    t0 = time.time()
    try:
        lowered, compiled, meta = lower_combo(arch, shape, mesh,
                                              kv_chunk=kv_chunk,
                                              overrides=overrides,
                                              microbatches=microbatches)
    except Exception as e:  # a failure here is a sharding bug
        if verbose:
            traceback.print_exc()
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
    out = RL.analyze(compiled, mesh)
    rl = out["roofline"]
    res = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "status": "ok", "compile_s": round(time.time() - t0, 1),
        **meta,
        "roofline": rl.row(),
        "collectives": {"bytes": out["collectives"].bytes_by_kind,
                        "count": out["collectives"].count_by_kind},
        "memory": out["memory"],
        "model_flops_6nd": model_flops(arch, shape),
    }
    if verbose:
        mem = out["memory"]
        print(f"[{arch} x {shape} x {'2pod' if multi_pod else '1pod'}] "
              f"compile {res['compile_s']}s  "
              f"args/dev {fmt_b(mem['argument_bytes'])}  "
              f"temp/dev {fmt_b(mem['temp_bytes'])}  "
              f"flops/dev {rl.flops:.3e}  dominant={rl.dominant}", flush=True)
    return res


def model_flops(arch: str, shape: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens/step."""
    cfg = configs.get(arch)
    info = configs.SHAPES[shape]
    n = cfg.active_params_count()
    if info["kind"] == "train":
        tokens = info["batch"] * info["seq"]
        return 6.0 * n * tokens
    if info["kind"] == "prefill":
        tokens = info["batch"] * info["seq"]
        return 2.0 * n * tokens
    return 2.0 * n * info["batch"]  # decode: one token per sequence


def fmt_b(x: Optional[float]) -> str:
    if x is None:
        return "?"
    for u in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{u}"
        x /= 1024
    return f"{x:.1f}PB"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(configs.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--kv-chunk", type=int, default=1024)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    combos = []
    archs = list(configs.ALIASES) if (args.all or not args.arch) else [args.arch]
    shapes = list(configs.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                combos.append((a, s, mp))

    results = []
    for a, s, mp in combos:
        results.append(run_combo(a, s, multi_pod=mp, kv_chunk=args.kv_chunk))

    n_fail = sum(r["status"] == "FAIL" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_ok = sum(r["status"] == "ok" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), {n_fail} FAILED")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print(f"wrote {args.json}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
