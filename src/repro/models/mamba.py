"""Mamba (S6 selective state-space) block for the Jamba hybrid.

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t     (per channel, d_state wide)
    y_t = C_t h_t + D x_t

with input-dependent (selective) dt, B, C.  The sequence recurrence is a
``lax.scan``; decode carries (conv window, ssm state) -- O(1) per token.

Sharding: d_inner over ``model`` (the inner channels are independent, so the
scan needs no cross-shard communication -- the TPU-friendly property that
makes Jamba's 1:7 Mamba:attention ratio cheap on the ICI).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import sharding
from .config import ArchConfig
from .layers import dtype_of


class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_conv - 1, d_inner) trailing inputs for the conv
    ssm: jax.Array   # (B, d_inner, d_state) recurrent state


def dt_rank(cfg: ArchConfig) -> int:
    return max(cfg.d_model // 16, 1)


def init_mamba(key: jax.Array, cfg: ArchConfig):
    d, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    r = dt_rank(cfg)
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg)
    params = {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) * dc ** -0.5).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": (jax.random.normal(ks[2], (di, r + 2 * ds)) * di ** -0.5).astype(dt),
        "dt_proj_w": (jax.random.normal(ks[3], (r, di)) * r ** -0.5).astype(dt),
        "dt_proj_b": jnp.log(jnp.exp(
            jnp.exp(jax.random.uniform(ks[4], (di,), minval=math.log(1e-3),
                                       maxval=math.log(1e-1))) - 1.0) + 1e-9
                             ).astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (di, d)) * di ** -0.5).astype(dt),
    }
    specs = {
        "in_proj": P(None, "model"), "conv_w": P(None, "model"),
        "conv_b": P("model"), "x_proj": P("model", None),
        "dt_proj_w": P(None, "model"), "dt_proj_b": P("model"),
        "A_log": P("model", None), "D": P("model"),
        "out_proj": P("model", None),
    }
    return params, specs


def init_mamba_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.d_inner), dtype),
        ssm=jnp.zeros((batch, cfg.d_inner, cfg.mamba_d_state), jnp.float32),
    )


def _selective(cfg: ArchConfig, params, xc: jax.Array):
    """dt, B, C streams from the conv output.  xc: (..., d_inner)."""
    r, ds = dt_rank(cfg), cfg.mamba_d_state
    proj = xc @ params["x_proj"]
    dt_in, bb, cc = jnp.split(proj, [r, r + ds], axis=-1)
    dt = jax.nn.softplus((dt_in @ params["dt_proj_w"]).astype(jnp.float32)
                         + params["dt_proj_b"])                    # (..., di)
    return dt, bb.astype(jnp.float32), cc.astype(jnp.float32)


def mamba_block(cfg: ArchConfig, params, x: jax.Array, state: MambaState):
    """Full-sequence Mamba.  x: (B, S, d) -> (y, new_state)."""
    b, s, d = x.shape
    di, ds, dc = cfg.d_inner, cfg.mamba_d_state, cfg.mamba_d_conv

    xz = x @ params["in_proj"]                                    # (B, S, 2*di)
    xz = sharding.constraint(xz, P(sharding.batch_axes(), None, "model"))
    xi, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv over time, warm-started from state.conv
    xpad = jnp.concatenate([state.conv.astype(xi.dtype), xi], axis=1)
    conv = sum(xpad[:, i:i + s] * params["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu(conv + params["conv_b"])

    dt, bb, cc = _selective(cfg, params, xc)                      # (B,S,di),(B,S,ds)x2
    a = -jnp.exp(params["A_log"])                                 # (di, ds)
    xf = xc.astype(jnp.float32)

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp                                 # (B,di),(B,ds),(B,ds),(B,di)
        da = jnp.exp(dt_t[..., None] * a[None])                   # (B, di, ds)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(bb, 1, 0),
          jnp.moveaxis(cc, 1, 0), jnp.moveaxis(xf, 1, 0))
    h_fin, ys = jax.lax.scan(step, state.ssm, xs)                 # ys (S, B, di)
    y = jnp.moveaxis(ys, 0, 1) + xf * params["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    y = sharding.constraint(y, P(sharding.batch_axes(), None, None))
    new_state = MambaState(conv=xi[:, s - (dc - 1):].astype(state.conv.dtype)
                           if s >= dc - 1 else
                           jnp.concatenate([state.conv, xi], axis=1)[:, -(dc - 1):],
                           ssm=h_fin)
    return y, new_state


def decode_step(cfg: ArchConfig, params, x: jax.Array, state: MambaState):
    """One-token Mamba step.  x: (B, 1, d)."""
    b = x.shape[0]
    di, dc = cfg.d_inner, cfg.mamba_d_conv
    xz = x[:, 0] @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                             # (B, di)

    window = jnp.concatenate([state.conv.astype(xi.dtype), xi[:, None]], axis=1)  # (B, dc, di)
    # Same multiply-add order as mamba_block's sliced sum: the full-sequence
    # and decode paths must agree bitwise, or downstream top-k MoE routing
    # amplifies the rounding gap into different expert choices.
    conv = sum(window[:, i] * params["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu(conv + params["conv_b"])

    dt, bb, cc = _selective(cfg, params, xc)
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt[..., None] * a[None])
    h = da * state.ssm + (dt * xc.astype(jnp.float32))[..., None] * bb[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, cc) + xc.astype(jnp.float32) * params["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    return y[:, None], MambaState(conv=window[:, 1:].astype(state.conv.dtype), ssm=h)
