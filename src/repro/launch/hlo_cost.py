"""Hierarchical HLO cost model with while-loop trip-count multiplication.

``compiled.cost_analysis()`` counts every computation exactly once -- a
``lax.scan`` of 60 layers contributes one layer's FLOPs (verified
empirically; see tests/test_hlo_cost.py).  For a framework whose entire
model stack is scanned (layers) and looped (microbatches, kv chunks,
recurrences), that underestimates FLOPs/bytes by 2-3 orders of magnitude.

This module parses ``compiled.as_text()`` (post-optimization HLO) into a
computation call graph and accumulates, per computation:

  * FLOPs: ``dot`` ops (2 * prod(out) * prod(contracting dims)) including
    dots nested inside fusions;
  * HBM bytes: per top-level instruction, operand bytes + output bytes --
    the canonical post-fusion traffic model (each fusion reads its operands
    once and writes its outputs once);
  * collective bytes/counts by kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), output-size
    convention.

and multiplies through the call graph:

  * ``while``: body + cond costs x ``known_trip_count`` (XLA annotates the
    trip count in backend_config for counted loops; default 1);
  * ``fusion`` / ``call``: called computation x 1 (FLOPs only for fusions --
    their internal traffic stays in registers/VMEM);
  * ``conditional``: every branch x 1 (upper bound).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction: [ROOT] %name = <shape> opcode(...)...
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\(?[^)]*?\)?\s*?[\w\[\],{}\s]*?)\s+"
    r"([\w\-]+)\((.*)$")
# simpler fallback: capture name, then everything, then find opcode
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*?)([\w\-]+)\((.*)\)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_TARGET_RE = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)=\{?(%?[\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _shape_list(shape_text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _shape_list(shape_text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class Instr:
    name: str
    shape_text: str
    opcode: str
    rest: str               # everything after the opening paren
    line: str

    @property
    def out_bytes(self) -> int:
        return _shape_bytes(self.shape_text)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # %name -> shape text


@dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v * mult

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


# opcodes whose operand/output bytes are NOT HBM traffic at this level
_PLUMBING = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota", "partition-id", "replica-id"}
_CONTROL = {"while", "call", "fusion", "conditional", "async-start",
            "async-done", "async-update", "custom-call"}


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            # computation header:  [ENTRY] %name (args...) -> result {
            if stripped.endswith("{") and "->" in stripped and \
                    stripped.startswith(("%", "ENTRY ")):
                head = stripped.split("(")[0].strip()
                is_entry = stripped.startswith("ENTRY")
                name = head.replace("ENTRY", "").strip()
                if name:
                    cur = Computation(name=name)
                    if is_entry:
                        entry = name
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(stripped)
        if not m:
            continue
        name, shape_text, opcode, rest = m.groups()
        inst = Instr(name=name, shape_text=shape_text, opcode=opcode,
                     rest=rest, line=stripped)
        cur.instrs.append(inst)
        cur.shapes[name] = shape_text
    return comps, entry


def _dot_flops(inst: Instr, comp: Computation) -> float:
    ops = _OPERAND_RE.findall(inst.rest.split(")")[0])
    if not ops:
        return 0.0
    lhs_shape = comp.shapes.get(ops[0], "")
    shapes = _shape_list(lhs_shape)
    if not shapes:
        return 0.0
    lhs_dims = shapes[0][1]
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
    contract = [int(d) for d in mc.group(1).split(",") if d] if mc else []
    k = 1
    for d in contract:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    out = 1
    for _, dims in _shape_list(inst.shape_text):
        for d in dims:
            out *= d
    return 2.0 * out * k


def _operand_names(inst: Instr) -> List[str]:
    depth, end = 0, len(inst.rest)
    for i, ch in enumerate(inst.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    return _OPERAND_RE.findall(inst.rest[:end])


def _operand_bytes(inst: Instr, comp: Computation) -> int:
    return sum(_shape_bytes(comp.shapes.get(op, ""))
               for op in _operand_names(inst))


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_computations(hlo_text)
        self._memo: Dict[str, CostTotals] = {}
        # computations reachable only as fusion bodies: traffic is internal
        self._fusion_bodies = set()
        for comp in self.comps.values():
            for inst in comp.instrs:
                if inst.opcode == "fusion":
                    m = re.search(r"calls=(%?[\w.\-]+)", inst.line)
                    if m:
                        self._fusion_bodies.add(m.group(1))

    # ------------------------------------------------------------------
    # Sliced-access refinement.  A scan body accesses its stacked xs
    # through dynamic-slice (and writes ys through dynamic-update-slice);
    # the physical traffic is the slice, not the full operand.  XLA fuses
    # the slice into consumers, so the refinement must look *through*
    # fusion parameters.
    # ------------------------------------------------------------------

    def _param_effective_bytes(self, fc_name: str) -> Dict[int, int]:
        """For fusion body ``fc_name``: parameter index -> effective bytes
        (slice sizes when the parameter is consumed only via
        dynamic-slice / as the destination of dynamic-update-slice)."""
        comp = self.comps.get(fc_name)
        if comp is None:
            return {}
        # parameter name by index
        pidx: Dict[str, int] = {}
        for inst in comp.instrs:
            if inst.opcode == "parameter":
                m = re.match(r"^(\d+)", inst.rest)
                if m:
                    pidx[inst.name] = int(m.group(1))
        consumers: Dict[str, List[Tuple[Instr, int]]] = {}
        for inst in comp.instrs:
            for pos, op in enumerate(_operand_names(inst)):
                if op in pidx:
                    consumers.setdefault(op, []).append((inst, pos))
        out: Dict[int, int] = {}
        for pname, uses in consumers.items():
            sliced = 0
            ok = True
            for inst, pos in uses:
                if inst.opcode == "dynamic-slice" and pos == 0:
                    sliced += inst.out_bytes
                elif inst.opcode == "dynamic-update-slice" and pos == 0:
                    # destination: in-place update, traffic ~ update size
                    ops = _operand_names(inst)
                    upd = _shape_bytes(comp.shapes.get(ops[1], "")) if len(ops) > 1 else 0
                    sliced += upd
                else:
                    ok = False
                    break
            if ok and sliced:
                out[pidx[pname]] = sliced
        return out

    def _fusion_hbm_bytes(self, inst: Instr, comp: Computation) -> int:
        m = re.search(r"calls=(%?[\w.\-]+)", inst.line)
        eff = self._param_effective_bytes(m.group(1)) if m else {}
        total = 0
        ops = _operand_names(inst)
        for i, op in enumerate(ops):
            full = _shape_bytes(comp.shapes.get(op, ""))
            total += min(eff.get(i, full), full)
        # output: if the fusion root is a dynamic-update-slice the result
        # aliases the destination -- write traffic ~ the updated slice
        fc = self.comps.get(m.group(1)) if m else None
        out_b = inst.out_bytes
        if fc and fc.instrs:
            root = fc.instrs[-1]
            if root.opcode == "dynamic-update-slice":
                rops = _operand_names(root)
                if len(rops) > 1:
                    out_b = _shape_bytes(fc.shapes.get(rops[1], "")) or out_b
        return total + out_b

    # ------------------------------------------------------------------
    def cost_of(self, name: str, *, as_fusion: bool = False) -> CostTotals:
        key = (name, as_fusion)
        if key in self._memo:
            return self._memo[key]
        total = CostTotals()
        comp = self.comps.get(name)
        if comp is None:
            return total
        self._memo[key] = total  # break cycles defensively
        for inst in comp.instrs:
            op = inst.opcode
            kind = next((k for k in COLLECTIVE_KINDS
                         if op == k or op.startswith(k + "-")), None)
            if kind:
                b = inst.out_bytes
                total.coll_bytes[kind] = total.coll_bytes.get(kind, 0.0) + b
                total.coll_count[kind] = total.coll_count.get(kind, 0.0) + 1
                total.hbm_bytes += inst.out_bytes + _operand_bytes(inst, comp)
                continue
            if op == "dot":
                total.flops += _dot_flops(inst, comp)
                if not as_fusion:
                    total.hbm_bytes += inst.out_bytes + _operand_bytes(inst, comp)
                continue
            if op == "while":
                trips = 1
                m = _TRIP_RE.search(inst.line)
                if m:
                    trips = int(m.group(1))
                mb = re.search(r"body=(%?[\w.\-]+)", inst.line)
                mc = re.search(r"condition=(%?[\w.\-]+)", inst.line)
                if mb:
                    total.add(self.cost_of(mb.group(1)), trips)
                if mc:
                    total.add(self.cost_of(mc.group(1)), trips)
                continue
            if op == "fusion":
                m = re.search(r"calls=(%?[\w.\-]+)", inst.line)
                if m:
                    inner = self.cost_of(m.group(1), as_fusion=True)
                    total.flops += inner.flops
                    for k, v in inner.coll_bytes.items():
                        total.coll_bytes[k] = total.coll_bytes.get(k, 0.0) + v
                    for k, v in inner.coll_count.items():
                        total.coll_count[k] = total.coll_count.get(k, 0.0) + v
                total.hbm_bytes += self._fusion_hbm_bytes(inst, comp)
                continue
            if op == "dynamic-slice":
                total.hbm_bytes += 2 * inst.out_bytes  # read slice + write
                continue
            if op == "dynamic-update-slice":
                ops = _operand_names(inst)
                upd = _shape_bytes(comp.shapes.get(ops[1], "")) if len(ops) > 1 \
                    else inst.out_bytes
                total.hbm_bytes += 2 * upd             # read update + write slice
                continue
            if op in ("call", "custom-call"):
                m = re.search(r"to_apply=(%?[\w.\-]+)", inst.line)
                if m:
                    total.add(self.cost_of(m.group(1)), 1.0)
                elif op == "custom-call":
                    total.hbm_bytes += inst.out_bytes + _operand_bytes(inst, comp)
                continue
            if op == "conditional":
                for m in re.finditer(r"(?:true_computation|false_computation|"
                                     r"branch_computations)=\{?([^},]+)", inst.line):
                    for target in m.group(1).split(","):
                        total.add(self.cost_of(target.strip()), 1.0)
                continue
            if op in _PLUMBING:
                continue
            if not as_fusion:
                # generic op at top level: reads operands, writes output
                total.hbm_bytes += inst.out_bytes + _operand_bytes(inst, comp)
        self._memo[key] = total
        return total

    def entry_cost(self) -> CostTotals:
        if self.entry is None:
            return CostTotals()
        return self.cost_of(self.entry)


    # ------------------------------------------------------------------
    # Attribution: which instructions carry the HBM traffic?
    # ------------------------------------------------------------------

    def top_hbm(self, n: int = 20) -> List[Tuple[float, str]]:
        """Top-n instructions by trip-multiplied HBM bytes."""
        acc: Dict[str, float] = {}

        def walk(name: str, mult: float, depth: int = 0):
            comp = self.comps.get(name)
            if comp is None or depth > 32:
                return
            for inst in comp.instrs:
                op = inst.opcode
                if op in _PLUMBING:
                    continue
                if op == "while":
                    trips = 1
                    m = _TRIP_RE.search(inst.line)
                    if m:
                        trips = int(m.group(1))
                    mb = re.search(r"body=(%?[\w.\-]+)", inst.line)
                    if mb:
                        walk(mb.group(1), mult * trips, depth + 1)
                    continue
                if op in ("call",):
                    m = re.search(r"to_apply=(%?[\w.\-]+)", inst.line)
                    if m:
                        walk(m.group(1), mult, depth + 1)
                    continue
                if op == "fusion":
                    b = self._fusion_hbm_bytes(inst, comp)
                elif op == "dynamic-slice":
                    b = 2 * inst.out_bytes
                elif op == "dynamic-update-slice":
                    ops = _operand_names(inst)
                    upd = _shape_bytes(comp.shapes.get(ops[1], "")) \
                        if len(ops) > 1 else inst.out_bytes
                    b = 2 * upd
                else:
                    b = inst.out_bytes + _operand_bytes(inst, comp)
                if b:
                    key = f"{op} {inst.shape_text.strip()[:60]}"
                    meta = re.search(r'op_name="([^"]*)"', inst.line)
                    if meta:
                        key += f"  [{meta.group(1)[-70:]}]"
                    acc[key] = acc.get(key, 0.0) + b * mult

        if self.entry:
            walk(self.entry, 1.0)
        return sorted(((v, k) for k, v in acc.items()), reverse=True)[:n]

    def top_collectives(self, n: int = 20) -> List[Tuple[float, str]]:
        """Top-n collective instructions by trip-multiplied bytes."""
        acc: Dict[str, float] = {}

        def walk(name: str, mult: float, depth: int = 0):
            comp = self.comps.get(name)
            if comp is None or depth > 32:
                return
            for inst in comp.instrs:
                op = inst.opcode
                if op == "while":
                    trips = 1
                    m = _TRIP_RE.search(inst.line)
                    if m:
                        trips = int(m.group(1))
                    mb = re.search(r"body=(%?[\w.\-]+)", inst.line)
                    if mb:
                        walk(mb.group(1), mult * trips, depth + 1)
                    continue
                if op in ("call", "fusion"):
                    m = re.search(r"(?:to_apply|calls)=(%?[\w.\-]+)", inst.line)
                    if m:
                        walk(m.group(1), mult, depth + 1)
                    continue
                kind = next((k for k in COLLECTIVE_KINDS
                             if op == k or op.startswith(k + "-")), None)
                if kind:
                    key = f"{kind} {inst.shape_text.strip()[:70]}"
                    meta = re.search(r'op_name="([^"]*)"', inst.line)
                    if meta:
                        key += f"  [{meta.group(1)[-70:]}]"
                    acc[key] = acc.get(key, 0.0) + inst.out_bytes * mult

        if self.entry:
            walk(self.entry, 1.0)
        return sorted(((v, k) for k, v in acc.items()), reverse=True)[:n]


def analyze_text(hlo_text: str) -> CostTotals:
    return HloCostModel(hlo_text).entry_cost()
