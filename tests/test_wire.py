"""Wire-format suite: the bitstream layer must be lossless and auditable.

Four layers of contract, lowest first:

* **bitio** -- MSB-first packing round-trips arbitrary field widths and
  IEEE-754 f32 bit patterns exactly; misuse (overflow values, overruns,
  nonzero padding) fails loudly.
* **codecs** -- every channel-family payload (MRC indices, block-plan
  headers, sign bitmaps, top-k records, dense f32) round-trips bitwise
  and writes *exactly* the bits the BitMeter books for it.
* **framing** -- Message/WireSession serialize to one self-describing
  byte stream that parses back field-for-field; the golden file pins the
  byte-level layout (regenerate with ``REGEN_GOLDEN=1`` after a
  deliberate, DESIGN.md-documented format bump).
* **audit** -- for every registry scheme: the channel wire hooks decode
  to the exact arrays the direct path produces, and a 3-round
  ``wire="audit"`` engine run is bit-identical to the direct host run
  with the stream length reconciling against the booked bits.

The reconcile tolerance contract and the frame-header width are
tripwired against DESIGN.md: widening either constant without updating
the documented value is a test failure by construction.
"""
import math
import os
import pathlib
import re
from types import SimpleNamespace

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bernoulli import bern_kl, clip01
from repro.core.bitmeter import BitMeter, ReconcileError
from repro.core.quantizers import sign_bits, topk_bits
from repro.fl import registry
from repro.fl.channels import BlockPlan, RoundContext, WireEnv
from repro.fl.data import make_synthetic, partition_iid
from repro.fl.engine import EngineSpec, FLEngine, MeanDeltaAggregator
from repro.fl.nets import make_mlp
from repro.fl.tasks import make_cfl_task, make_mask_task
from repro.wire import (DIR_CTRL, DIR_DOWN, DIR_FLUSH_DOWN, DIR_FLUSH_UP,
                        DIR_UP, DOWNLINK_DIRS, FRAME_HEADER_BITS,
                        FRAME_OVERHEAD_BITS, FRAME_TRAILER_BITS, MAGIC,
                        RECONCILE_REL_TOL, RECONCILE_TOL_BITS, SERVER,
                        UPLINK_DIRS, VERSION, BitReader, BitWriter, Message,
                        WireCapacityError, WireFormatError, WireSession,
                        codecs, scheme_wire_id)

N, D = 3, 96
SCHEMES = registry.all_schemes(n=N, d=D, n_is=8, block=32, reset_period=2,
                               include_adaptive=True)
SCHEME_IDS = [s[0] for s in SCHEMES]

REPO = pathlib.Path(__file__).resolve().parent.parent
GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"

# Engine-level fixtures use a small MLP; keep ENGINE_D in sync (asserted
# in the fixture) so the registry's m3 top-k budget matches the model.
ENGINE_D = 208
ENGINE_SCHEMES = registry.all_schemes(n=N, d=ENGINE_D, n_is=8, block=32,
                                      reset_period=2, include_adaptive=True)


# ---------------------------------------------------------------------------
# bitio: MSB-first packing.
# ---------------------------------------------------------------------------


class TestBitIO:
    @settings(max_examples=8)
    @given(st.integers(min_value=0, max_value=2 ** 48 - 1),
           st.integers(min_value=1, max_value=48))
    def test_field_roundtrip(self, value, width):
        value &= (1 << width) - 1
        w = BitWriter()
        w.write(value, width)
        assert w.bits_written == width
        r = BitReader(w.getvalue(), w.bits_written)
        assert r.read(width) == value
        r.expect_exhausted()

    def test_mixed_width_stream_roundtrip(self):
        rng = np.random.default_rng(0)
        widths = rng.integers(1, 40, size=200)
        values = [int(rng.integers(0, 1 << wd)) for wd in widths]
        w = BitWriter()
        for v, wd in zip(values, widths):
            w.write(v, int(wd))
        assert w.bits_written == int(widths.sum())
        data = w.getvalue()
        assert len(data) == -(-w.bits_written // 8)
        assert w.getvalue() == data  # non-destructive
        r = BitReader(data, w.bits_written)
        for v, wd in zip(values, widths):
            assert r.read(int(wd)) == v
        r.expect_exhausted()

    def test_f32_bit_exact_roundtrip(self):
        specials = np.array([0.0, -0.0, 1.5, -2.25, np.inf, -np.inf,
                             np.nan, np.float32(1e-45),  # denormal
                             np.float32(3.4028235e38)], dtype=np.float32)
        for aligned in (True, False):
            w = BitWriter()
            if not aligned:
                w.write(1, 3)  # force the bit-by-bit path
            w.write_f32_array(specials)
            r = BitReader(w.getvalue(), w.bits_written)
            if not aligned:
                assert r.read(3) == 1
            out = r.read_f32_array(len(specials))
            np.testing.assert_array_equal(out.view(np.uint32),
                                          specials.view(np.uint32))

    def test_read_payload_unaligned_equals_aligned(self):
        rng = np.random.default_rng(1)
        payload = rng.integers(0, 256, size=17, dtype=np.uint8).tobytes()
        nbits = 131
        w = BitWriter()
        w.write_bits(payload, nbits)
        aligned, _ = BitReader(w.getvalue(), w.bits_written).read_payload(nbits)
        w2 = BitWriter()
        w2.write(0, 5)
        w2.write_bits(payload, nbits)
        r2 = BitReader(w2.getvalue(), w2.bits_written)
        r2.read(5)
        unaligned, _ = r2.read_payload(nbits)
        assert unaligned == aligned

    def test_misuse_is_loud(self):
        w = BitWriter()
        with pytest.raises(WireFormatError):
            w.write(4, 2)  # value does not fit
        with pytest.raises(WireFormatError):
            w.write(-1, 8)
        w.write(3, 2)
        r = BitReader(w.getvalue(), w.bits_written)
        with pytest.raises(WireFormatError):
            r.read(3)  # overruns the 2-bit stream
        with pytest.raises(WireFormatError):
            BitReader(b"\x00", 9)  # promises more bits than bytes
        ww = BitWriter()
        ww.write(3, 2)  # second bit is nonzero where padding is expected
        rr = BitReader(ww.getvalue(), 8)
        rr.read(1)
        with pytest.raises(WireFormatError):
            rr.align()

    def test_align_pads_with_zeros(self):
        w = BitWriter()
        w.write(5, 3)
        pad = w.align()
        assert pad == 5 and w.bits_written == 8
        r = BitReader(w.getvalue(), 8)
        assert r.read(3) == 5
        r.align()
        r.expect_exhausted()


# ---------------------------------------------------------------------------
# codecs: payloads write exactly the booked bits and round-trip bitwise.
# ---------------------------------------------------------------------------


class TestCodecs:
    def test_indices_roundtrip_at_booked_width(self):
        rng = np.random.default_rng(2)
        idx = rng.integers(0, 8, size=(2, 5, 3))
        w = BitWriter()
        codecs.put_indices(w, idx, 8)
        assert w.bits_written == idx.size * math.log2(8)  # booked rate
        r = BitReader(w.getvalue(), w.bits_written)
        np.testing.assert_array_equal(codecs.get_indices(r, idx.shape, 8), idx)
        r.expect_exhausted()

    def test_non_pow2_n_is_rejected(self):
        # log2(6) books fractional bits/index -- no integer codec can match
        with pytest.raises(WireCapacityError):
            codecs.index_width(6)

    def test_plan_avg_roundtrip(self):
        w = BitWriter()
        codecs.put_plan_avg(w, 64, 256)
        assert w.bits_written == math.ceil(math.log2(256))
        r = BitReader(w.getvalue(), w.bits_written)
        assert codecs.get_plan_avg(r, 256) == 64
        with pytest.raises(WireCapacityError):
            codecs.put_plan_avg(BitWriter(), 48, 256)  # not a pow2 size

    def test_plan_segments_roundtrip_self_delimiting(self):
        rng = np.random.default_rng(3)
        max_block = 64
        lengths = rng.integers(1, max_block + 1, size=9)
        seg = np.repeat(np.arange(len(lengths)), lengths)
        d = int(lengths.sum())
        w = BitWriter()
        codecs.put_plan_segments(w, seg, max_block)
        assert w.bits_written == len(lengths) * math.ceil(math.log2(max_block))
        r = BitReader(w.getvalue(), w.bits_written)
        np.testing.assert_array_equal(codecs.get_plan_segments(r, d,
                                                               max_block), seg)
        r.expect_exhausted()

    def test_plan_segments_capacity_and_tiling_errors(self):
        with pytest.raises(WireCapacityError):
            codecs.put_plan_segments(BitWriter(), np.zeros(65, np.int64), 64)
        w = BitWriter()
        w.write(7, 6)  # one segment of length 8 cannot tile d=5
        with pytest.raises(WireFormatError):
            codecs.get_plan_segments(BitReader(w.getvalue(), 6), 5, 64)

    def test_plan_segments_rejects_non_monotone(self):
        # the header is run-length coded: a permuted seg_ids has the same
        # bincount and would round-trip to a *different* segmentation
        good = np.repeat(np.arange(3), [2, 5, 1])
        with pytest.raises(WireFormatError, match="non-decreasing"):
            codecs.put_plan_segments(BitWriter(), good[::-1], 8)
        with pytest.raises(WireFormatError, match="non-decreasing"):
            codecs.put_plan_segments(BitWriter(), good + 1, 8)

    def test_sign_pass_roundtrip_at_booked_rate(self):
        rng = np.random.default_rng(4)
        d = 45  # not a byte multiple: bitmap padding is in the frame, not here
        signs = rng.random(d) < 0.5
        scale = np.float32(0.037)
        w = BitWriter()
        codecs.put_sign_pass(w, scale, signs)
        assert w.bits_written == sign_bits(d)  # d + 32
        r = BitReader(w.getvalue(), w.bits_written)
        s2, b2 = codecs.get_sign_pass(r, d)
        assert np.float32(s2).view(np.uint32) == scale.view(np.uint32)
        np.testing.assert_array_equal(b2, signs)
        r.expect_exhausted()

    def test_topk_roundtrip_at_booked_rate(self):
        rng = np.random.default_rng(5)
        d, k = 200, 7
        idx = rng.choice(d, size=k, replace=False)
        val = rng.standard_normal(k).astype(np.float32)
        w = BitWriter()
        codecs.put_topk(w, idx, val, d)
        assert w.bits_written == topk_bits(d, k)
        r = BitReader(w.getvalue(), w.bits_written)
        i2, v2 = codecs.get_topk(r, k, d)
        np.testing.assert_array_equal(i2, idx)
        np.testing.assert_array_equal(v2.view(np.uint32), val.view(np.uint32))
        r.expect_exhausted()

    def test_dense_roundtrip(self):
        rng = np.random.default_rng(6)
        xs = rng.standard_normal(33).astype(np.float32)
        w = BitWriter()
        codecs.put_dense(w, xs)
        assert w.bits_written == 32 * xs.size
        r = BitReader(w.getvalue(), w.bits_written)
        np.testing.assert_array_equal(codecs.get_dense(r, xs.size)
                                      .view(np.uint32), xs.view(np.uint32))
        r.expect_exhausted()


# ---------------------------------------------------------------------------
# Framing: messages and sessions.
# ---------------------------------------------------------------------------


class TestFraming:
    def test_header_width_is_pinned(self):
        assert FRAME_HEADER_BITS == 144
        assert FRAME_TRAILER_BITS == 32
        assert FRAME_OVERHEAD_BITS == 144 + 32 == 176
        assert MAGIC == 0xB1C0 and VERSION == 2

    def test_message_roundtrip(self):
        m = Message(direction=DIR_UP, sender=2, recipient=SERVER,
                    payload=b"\xAB\xC0", payload_bits=11, round=9,
                    scheme_id=0x1234)
        w = BitWriter()
        m.write_to(w)
        assert w.bits_written == m.frame_bits == FRAME_OVERHEAD_BITS + 16
        m2 = Message.read_from(BitReader(w.getvalue(), w.bits_written))
        assert m2 == m

    def test_message_validation(self):
        with pytest.raises(WireFormatError):
            Message(direction=99, sender=0, recipient=0, payload=b"",
                    payload_bits=0)
        with pytest.raises(WireFormatError):  # 1 byte cannot carry 9 bits
            Message(direction=DIR_UP, sender=0, recipient=0, payload=b"\x00",
                    payload_bits=9)
        with pytest.raises(WireFormatError):  # 2 bytes for 3 bits: over-padded
            Message(direction=DIR_UP, sender=0, recipient=0,
                    payload=b"\x00\x00", payload_bits=3)

    def test_session_roundtrip_and_direction_totals(self):
        s = WireSession(scheme_id=77)
        s.add([Message(direction=DIR_UP, sender=0, recipient=SERVER,
                       payload=b"\xF0", payload_bits=4),
               Message(direction=DIR_CTRL, sender=1, recipient=SERVER,
                       payload=b"\x80", payload_bits=1)], round=0)
        s.add([Message(direction=DIR_DOWN, sender=SERVER, recipient=0,
                       payload=b"\x01\x02\x03", payload_bits=24)], round=1)
        p = WireSession.parse(s.to_bytes())
        assert p.scheme_id == 77
        assert [(m.round, m.direction, m.sender, m.recipient, m.payload_bits,
                 m.payload) for m in p.messages] == \
               [(m.round, m.direction, m.sender, m.recipient, m.payload_bits,
                 m.payload) for m in s.messages]
        assert s.uplink_payload_bits == 5
        assert s.downlink_payload_bits == 24
        assert s.stream_bits == 3 * FRAME_OVERHEAD_BITS + 8 + 8 + 24
        lo = 3 * FRAME_OVERHEAD_BITS
        assert lo <= s.framing_bits <= lo + 3 * 7

    def test_parse_rejects_bad_magic_and_version(self):
        m = Message(direction=DIR_UP, sender=0, recipient=SERVER,
                    payload=b"", payload_bits=0)
        w = BitWriter()
        m.write_to(w)
        data = bytearray(w.getvalue())
        bad = bytes([0xDE, 0xAD]) + bytes(data[2:])
        with pytest.raises(WireFormatError, match="magic"):
            WireSession.parse(bad)
        data[2] = VERSION + 1
        with pytest.raises(WireFormatError, match="version"):
            WireSession.parse(bytes(data))


# ---------------------------------------------------------------------------
# Reconcile: loud on divergence, envelope-checked on framing.
# ---------------------------------------------------------------------------


class TestReconcile:
    def _meter(self, ul=1000.0, dl=500.0):
        m = BitMeter(n_clients=N, d=D)
        m.add_round(ul, dl)
        return m

    def test_exact_match_passes(self):
        rep = self._meter().reconcile(1000, 500, framing_bits=2 * 176,
                                      n_messages=2, frame_overhead_bits=176)
        assert rep["uplink_err_bits"] == 0.0
        assert rep["downlink_err_bits"] == 0.0

    def test_payload_divergence_raises(self):
        with pytest.raises(ReconcileError, match="uplink"):
            self._meter().reconcile(999, 500)
        with pytest.raises(ReconcileError, match="downlink"):
            self._meter().reconcile(1000, 501)

    def test_rel_tol_absorbs_float_bookkeeping_only(self):
        m = self._meter(ul=1e9)
        m.reconcile(1e9 + 0.5, 500)  # within 1e-9 relative slack
        with pytest.raises(ReconcileError):
            m.reconcile(1e9 + 10.0, 500)

    def test_framing_envelope_raises(self):
        with pytest.raises(ReconcileError, match="framing"):
            self._meter().reconcile(1000, 500, framing_bits=10.0,
                                    n_messages=2, frame_overhead_bits=176)
        with pytest.raises(ReconcileError, match="framing"):
            self._meter().reconcile(1000, 500,
                                    framing_bits=2 * (176 + 7) + 1,
                                    n_messages=2, frame_overhead_bits=176)

    def test_session_reconcile_is_loud(self):
        s = WireSession(scheme_id=1)
        s.add([Message(direction=DIR_UP, sender=0, recipient=SERVER,
                       payload=b"\x00" * 125, payload_bits=1000)], round=0)
        m = BitMeter(n_clients=N, d=D)
        m.add_round(1000.0, 0.0)
        s.reconcile(m)  # exact: passes
        m.add_round(1.0, 0.0)  # book a bit that never hit the wire
        with pytest.raises(ReconcileError):
            s.reconcile(m)


# ---------------------------------------------------------------------------
# Channel-level audit: hooks are lossless and write the booked bits.
# (Same fixture pattern as tests/test_bit_accounting.py.)
# ---------------------------------------------------------------------------


def _round_inputs(kind: str, key: int = 0):
    rng = np.random.default_rng(key)
    if kind == "mask":
        payload = jnp.asarray(rng.uniform(0.05, 0.95, (N, D)), jnp.float32)
        priors = jnp.asarray(rng.uniform(0.05, 0.95, (N, D)), jnp.float32)
        theta = jnp.asarray(rng.uniform(0.05, 0.95, D), jnp.float32)
    else:
        payload = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
        priors = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
        theta = jnp.asarray(rng.standard_normal(D), jnp.float32)
    return payload, priors, theta


def _host_plan(spec, payload, priors):
    if spec.allocation is None:
        return None
    kl = None
    if getattr(spec.allocation, "needs_kl", True):
        kl = np.asarray(jnp.mean(jax.vmap(bern_kl)(payload, clip01(priors)),
                                 axis=0))
    size, n_blocks, seg_ids, overhead = spec.allocation.plan(kl, D)
    return BlockPlan(size=size, n_blocks=n_blocks, seg_ids=seg_ids,
                     overhead_bits=overhead)


def _ctx(spec, payload, priors):
    plan = _host_plan(spec, payload, priors)
    return RoundContext(t=0, key=jax.random.PRNGKey(7), n_clients=N, d=D,
                        active=np.arange(N), plan=plan)


def _reset(spec):
    for chan in (spec.uplink, spec.downlink):
        reset = getattr(chan, "reset", None)
        if reset is not None:
            reset()


def _bits_close(stream_bits, booked):
    return math.isclose(stream_bits, booked,
                        rel_tol=RECONCILE_REL_TOL, abs_tol=RECONCILE_TOL_BITS)


@pytest.mark.parametrize("name,kind,factory", SCHEMES, ids=SCHEME_IDS)
def test_channel_hooks_lossless_and_stream_matches_booked(name, kind, factory):
    spec = factory()
    payload, priors, theta = _round_inputs(kind)
    ctx = _ctx(spec, payload, priors)
    theta_hat = jnp.tile(theta[None], (N, 1))

    # direct reference round
    up_direct, ul_direct = spec.uplink.transmit(ctx, payload, priors)
    update = spec.aggregator(ctx, theta, up_direct)
    th_d, thh_d, dl_direct = spec.downlink.distribute(ctx, update, theta,
                                                      theta_hat)
    _reset(spec)

    # wire round: encode -> decode drives everything
    _, ul_wire, up_msgs = spec.uplink.transmit_wire(ctx, payload, priors)
    up_dec = spec.uplink.decode_up(ctx, up_msgs, priors)
    np.testing.assert_array_equal(np.asarray(up_dec), np.asarray(up_direct))
    assert ul_wire == ul_direct, name

    update_w = spec.aggregator(ctx, theta, up_dec)
    _, dn_msgs = spec.downlink.distribute_wire(ctx, update_w, theta,
                                               theta_hat, up_msgs)
    env = WireEnv(uplink=spec.uplink, aggregator=spec.aggregator,
                  priors=priors, up_msgs=up_msgs, update=update_w)
    th_w, thh_w, dl_wire = spec.downlink.decode_down(ctx, dn_msgs, theta,
                                                     theta_hat, env)
    np.testing.assert_array_equal(np.asarray(th_w), np.asarray(th_d))
    np.testing.assert_array_equal(np.asarray(thh_w), np.asarray(thh_d))
    assert dl_wire == dl_direct, name

    # serialized payload length == booked channel bits, per direction
    assert all(m.direction == DIR_UP for m in up_msgs), name
    assert all(m.direction == DIR_DOWN for m in dn_msgs), name
    assert _bits_close(sum(m.payload_bits for m in up_msgs), ul_direct), name
    assert _bits_close(sum(m.payload_bits for m in dn_msgs), dl_direct), name


@pytest.mark.parametrize("name,kind,factory",
                         [s for s in SCHEMES if s[2]().allocation is not None],
                         ids=[s[0] for s in SCHEMES
                              if s[2]().allocation is not None])
def test_plan_header_roundtrip_at_booked_overhead(name, kind, factory):
    spec = factory()
    payload, priors, _ = _round_inputs(kind)
    plan = _host_plan(spec, payload, priors)
    w = BitWriter()
    spec.allocation.encode_plan(plan, w)
    assert w.bits_written == plan.overhead_bits, name  # header == booked
    r = BitReader(w.getvalue(), w.bits_written)
    plan2 = spec.allocation.decode_plan(r, D)
    r.expect_exhausted()
    assert plan2.size == plan.size and plan2.n_blocks == plan.n_blocks, name
    assert float(plan2.overhead_bits) == float(plan.overhead_bits), name
    if plan.seg_ids is None:
        assert plan2.seg_ids is None, name
    else:
        np.testing.assert_array_equal(np.asarray(plan2.seg_ids),
                                      np.asarray(plan.seg_ids))


@pytest.mark.parametrize("scheme", ["cser", "liec"])
def test_flush_wire_matches_flush(scheme):
    mk = lambda: registry.baseline_spec(scheme, n=N, d=D, reset_period=2)
    payload, priors, theta = _round_inputs("delta")
    s1, s2 = mk(), mk()
    ctx1, ctx2 = _ctx(s1, payload, priors), _ctx(s2, payload, priors)
    s1.uplink.transmit(ctx1, payload, priors)  # populate the EF memories
    s2.uplink.transmit(ctx2, payload, priors)
    r1, b1 = s1.uplink.flush(N, D)
    _, b2, msgs = s2.uplink.flush_wire(N, D)
    assert b2 == b1
    assert len(msgs) == N
    assert all(m.direction == DIR_FLUSH_UP for m in msgs)
    assert _bits_close(sum(m.payload_bits for m in msgs), b1)
    dec = s2.uplink.decode_flush_up(msgs, N, D)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(r1))


# ---------------------------------------------------------------------------
# Engine-level audit: a wire-audited run is bit-identical to the direct run.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def wire_setup():
    k = jax.random.PRNGKey(6)
    train, test = make_synthetic(k, n_train=60, n_test=30, hw=4, noise=0.5)
    shards = partition_iid(jax.random.fold_in(k, 1), train, N, 20)
    net = make_mlp(in_dim=16, widths=(8,), signed_constant=True)
    mask_task = make_mask_task(net, jax.random.fold_in(k, 2), test.x, test.y,
                               local_epochs=1, batch_size=20)
    dnet = make_mlp(in_dim=16, widths=(8,))
    cfl_task, theta0 = make_cfl_task(dnet, jax.random.fold_in(k, 3), test.x,
                                     test.y, local_epochs=1, batch_size=20,
                                     local_lr=3e-3)
    assert int(theta0.shape[0]) == ENGINE_D  # keep ENGINE_SCHEMES' d in sync
    return mask_task, cfl_task, theta0, shards


@pytest.mark.parametrize("name,kind,factory", ENGINE_SCHEMES,
                         ids=[s[0] for s in ENGINE_SCHEMES])
def test_wire_audited_run_bit_identical(name, kind, factory, wire_setup):
    mask_task, cfl_task, theta0, shards = wire_setup
    task = mask_task if kind == "mask" else cfl_task
    t0 = None if kind == "mask" else theta0
    # reset_period=2 inside 3 rounds exercises the FLUSH_UP/FLUSH_DOWN frames
    direct = FLEngine(task, factory()).run(shards, t0, rounds=3, seed=1,
                                           mode="host")
    audited = FLEngine(task, factory()).run(shards, t0, rounds=3, seed=1,
                                            mode="host", wire="audit")

    np.testing.assert_array_equal(np.asarray(direct["theta"]),
                                  np.asarray(audited["theta"]))
    np.testing.assert_array_equal(np.asarray(direct["theta_hat"]),
                                  np.asarray(audited["theta_hat"]))
    assert audited["history"] == direct["history"], name
    assert audited["meter"] == direct["meter"], name

    # the reconcile report certifies stream length == booked bits
    rep = audited["wire"]
    assert rep["messages"] > 0, name
    session = audited["wire_session"]
    assert all(m.scheme_id == scheme_wire_id(factory().name)
               for m in session.messages), name

    # the stream survives serialization field-for-field
    parsed = WireSession.parse(session.to_bytes())
    assert [(m.round, m.direction, m.sender, m.recipient, m.payload_bits,
             m.payload) for m in parsed.messages] == \
           [(m.round, m.direction, m.sender, m.recipient, m.payload_bits,
             m.payload) for m in session.messages], name


def test_wire_audit_rejects_fused_mode(wire_setup):
    mask_task, _, _, shards = wire_setup
    eng = FLEngine(mask_task, ENGINE_SCHEMES[0][2]())
    with pytest.raises(ValueError, match="host path"):
        eng.run(shards, rounds=1, mode="fused", wire="audit")
    with pytest.raises(ValueError, match="wire="):
        eng.run(shards, rounds=1, mode="host", wire="bogus")


def test_wire_audit_rejects_unwireable_spec(wire_setup):
    mask_task, _, _, shards = wire_setup
    spec = EngineSpec(uplink=SimpleNamespace(), downlink=SimpleNamespace(),
                      aggregator=MeanDeltaAggregator(), name="no-wire")
    with pytest.raises(ValueError, match="cannot be wire-audited"):
        FLEngine(mask_task, spec).run(shards, rounds=1, mode="host",
                                      wire="audit")


def test_wire_audit_rejects_non_pow2_n_is_upfront(wire_setup):
    """A fractional-bit n_is must fail before any round work, naming the
    offending channel -- not as a WireCapacityError mid-run."""
    from repro.core.blocks import FixedAllocation
    mask_task, _, _, shards = wire_setup
    spec = registry.bicompfl_spec("GR", allocation=FixedAllocation(32),
                                  n_is=6, n_dl=N)
    eng = FLEngine(mask_task, spec)
    with pytest.raises(ValueError,
                       match=r"MRCFixedChannel has n_is=6"):
        eng.run(shards, rounds=3, seed=1, mode="host", wire="audit")
    # off the wire, a non-pow2 n_is is perfectly legal (bits are booked
    # at the information-theoretic log2 rate)
    out = FLEngine(mask_task, spec).run(shards, rounds=1, seed=1, mode="host")
    assert len(out["history"]) == 1


def test_registry_schemes_have_wireable_n_is():
    """Every registry scheme's channels book integer bits per MRC index."""
    for name, _, factory in SCHEMES:
        spec = factory()
        for chan in (spec.uplink, spec.downlink):
            n_is = getattr(chan, "n_is", None)
            if n_is is not None:
                codecs.index_width(n_is)  # raises WireCapacityError if not


def test_scheme_wire_ids_fit_header_without_collision():
    ids = registry.wire_scheme_ids(n=N, d=D)
    # adaptive variants reuse their base spec name -> distinct names, not rows
    names = {f().name for _, _, f in SCHEMES}
    assert set(ids) == names
    assert all(0 <= v <= 0xFFFF for v in ids.values())
    assert len(set(ids.values())) == len(ids)  # one header id per scheme


# ---------------------------------------------------------------------------
# Fused-program cache (PR satellite): repeated runs must not retrace.
# ---------------------------------------------------------------------------


def test_fused_program_cache_no_retrace(wire_setup):
    _, cfl_task, theta0, shards = wire_setup
    spec = lambda: registry.baseline_spec("fedavg", n=N, d=ENGINE_D)
    eng = FLEngine(cfl_task, spec())
    cold = eng.run(shards, theta0, rounds=2, seed=0, mode="fused")
    assert eng.fused_trace_count == 1
    # seed and eval cadence are runner *data*: cache hits, no retrace
    warm = eng.run(shards, theta0, rounds=2, seed=5, mode="fused")
    assert eng.fused_trace_count == 1
    eng.run(shards, theta0, rounds=2, seed=5, eval_every=2, mode="fused")
    assert eng.fused_trace_count == 1
    # a shape change (rounds) is a new signature: exactly one more trace
    eng.run(shards, theta0, rounds=3, seed=0, mode="fused")
    assert eng.fused_trace_count == 2

    # warm-path results are identical to a cold engine's
    fresh = FLEngine(cfl_task, spec()).run(shards, theta0, rounds=2, seed=5,
                                           mode="fused")
    np.testing.assert_array_equal(np.asarray(warm["theta"]),
                                  np.asarray(fresh["theta"]))
    assert warm["meter"] == fresh["meter"]
    assert warm["history"] == fresh["history"]
    assert cold["meter"]["rounds"] == 2


# ---------------------------------------------------------------------------
# Golden file: byte-level format stability.
# ---------------------------------------------------------------------------


def _golden_session() -> WireSession:
    """A deterministic session exercising every codec family."""
    s = WireSession(scheme_id=scheme_wire_id("golden-v1"))

    w = BitWriter()
    codecs.put_plan_segments(w, np.repeat(np.arange(3), [2, 5, 1]), 8)
    ctrl = Message(direction=DIR_CTRL, sender=0, recipient=SERVER,
                   payload=w.getvalue(), payload_bits=w.bits_written)

    w = BitWriter()
    codecs.put_indices(w, np.arange(12).reshape(3, 4) % 8, 8)
    up_idx = Message(direction=DIR_UP, sender=1, recipient=SERVER,
                     payload=w.getvalue(), payload_bits=w.bits_written)

    w = BitWriter()
    codecs.put_sign_pass(w, np.float32(0.5), [True, False] * 8 + [True])
    up_sign = Message(direction=DIR_UP, sender=2, recipient=SERVER,
                      payload=w.getvalue(), payload_bits=w.bits_written)

    w = BitWriter()
    codecs.put_topk(w, [3, 11, 4], np.float32([1.5, -2.25, 0.125]), 16)
    up_topk = Message(direction=DIR_FLUSH_UP, sender=0, recipient=SERVER,
                      payload=w.getvalue(), payload_bits=w.bits_written)

    w = BitWriter()
    codecs.put_dense(w, np.float32([0.0, -0.0, 3.5, -1e-8]))
    down = Message(direction=DIR_DOWN, sender=SERVER, recipient=1,
                   payload=w.getvalue(), payload_bits=w.bits_written)
    w = BitWriter()
    codecs.put_dense(w, np.float32([2.0, -4.0]))
    flush_dn = Message(direction=DIR_FLUSH_DOWN, sender=SERVER, recipient=2,
                       payload=w.getvalue(), payload_bits=w.bits_written)

    s.add([ctrl, up_idx, up_sign], round=0)
    s.add([up_topk, down, flush_dn], round=1)
    return s


def test_golden_wire_file_is_stable():
    """The serialized byte stream is the format contract.  A mismatch means
    the wire layout changed: bump VERSION, document the change in
    DESIGN.md, and regenerate with ``REGEN_GOLDEN=1 pytest -k golden``."""
    path = GOLDEN / "wire_session_v2.bin"
    data = _golden_session().to_bytes()
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN.mkdir(exist_ok=True)
        path.write_bytes(data)
    assert path.exists(), f"golden file missing; regenerate: " \
                          f"REGEN_GOLDEN=1 pytest {__file__} -k golden"
    assert path.read_bytes() == data

    # and it parses back to the exact field values written above
    p = WireSession.parse(path.read_bytes())
    assert p.scheme_id == scheme_wire_id("golden-v1")
    assert [m.direction for m in p.messages] == \
           [DIR_CTRL, DIR_UP, DIR_UP, DIR_FLUSH_UP, DIR_DOWN, DIR_FLUSH_DOWN]
    assert [m.round for m in p.messages] == [0, 0, 0, 1, 1, 1]
    r = BitReader(p.messages[0].payload, p.messages[0].payload_bits)
    np.testing.assert_array_equal(codecs.get_plan_segments(r, 8, 8),
                                  np.repeat(np.arange(3), [2, 5, 1]))
    r = BitReader(p.messages[1].payload, p.messages[1].payload_bits)
    np.testing.assert_array_equal(
        codecs.get_indices(r, (3, 4), 8), np.arange(12).reshape(3, 4) % 8)
    r = BitReader(p.messages[4].payload, p.messages[4].payload_bits)
    np.testing.assert_array_equal(
        codecs.get_dense(r, 4).view(np.uint32),
        np.float32([0.0, -0.0, 3.5, -1e-8]).view(np.uint32))


# ---------------------------------------------------------------------------
# DESIGN.md tripwire: the documented contract must equal the code constants.
# ---------------------------------------------------------------------------


def test_design_doc_pins_the_tolerance_contract():
    """Widening a reconcile tolerance or the frame header without updating
    the documented contract in DESIGN.md is a format change done wrong."""
    text = (REPO / "DESIGN.md").read_text()

    def documented(name):
        m = re.search(rf"`{name}`\s*=\s*([0-9e.+-]+)", text)
        assert m, f"DESIGN.md does not document {name}"
        return float(m.group(1))

    assert documented("FRAME_HEADER_BITS") == FRAME_HEADER_BITS == 144
    assert documented("RECONCILE_TOL_BITS") == RECONCILE_TOL_BITS == 0.0
    assert documented("RECONCILE_REL_TOL") == RECONCILE_REL_TOL == 1e-9
    assert documented("WIRE_VERSION") == VERSION == 2
    assert documented("FRAME_TRAILER_BITS") == FRAME_TRAILER_BITS == 32
    assert documented("FRAME_OVERHEAD_BITS") == FRAME_OVERHEAD_BITS == 176
