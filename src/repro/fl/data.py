"""Synthetic federated datasets + iid / Dirichlet(alpha) partitioning.

The container is offline, so MNIST/Fashion-MNIST/CIFAR-10 are replaced by a
controllable synthetic image-classification family: each class c has a
smooth random template T_c (low-frequency Gaussian field); samples are
T_c + sigma * noise, optionally passed through a fixed random projection to
decorrelate pixels.  Difficulty is controlled by ``noise``; accuracy trends
(not absolute values) are what the reproduction validates.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Dataset(NamedTuple):
    x: jax.Array  # (N, H, W, C) float32
    y: jax.Array  # (N,) int32


def _smooth_field(key, hw: int, smooth: int = 3) -> jax.Array:
    raw = jax.random.normal(key, (hw + 2 * smooth, hw + 2 * smooth))
    k = jnp.ones((2 * smooth + 1, 2 * smooth + 1)) / (2 * smooth + 1) ** 2
    sm = jax.scipy.signal.convolve2d(raw, k, mode="valid")
    sm = sm / (jnp.std(sm) + 1e-6)
    return sm[:hw, :hw]


def make_synthetic(
    key: jax.Array,
    *,
    n_train: int = 5000,
    n_test: int = 1000,
    n_classes: int = 10,
    hw: int = 14,
    channels: int = 1,
    noise: float = 0.9,
) -> Tuple[Dataset, Dataset]:
    kt, ktr, kte = jax.random.split(key, 3)
    templates = jax.vmap(lambda k: _smooth_field(k, hw))(jax.random.split(kt, n_classes * channels))
    templates = templates.reshape(n_classes, channels, hw, hw).transpose(0, 2, 3, 1)

    def sample(k, n):
        ky, kn = jax.random.split(k)
        y = jax.random.randint(ky, (n,), 0, n_classes)
        x = templates[y] + noise * jax.random.normal(kn, (n, hw, hw, channels))
        return Dataset(x=x.astype(jnp.float32), y=y.astype(jnp.int32))

    return sample(ktr, n_train), sample(kte, n_test)


# ---------------------------------------------------------------------------
# Partitioning.  Shards are equal-sized (sampling with replacement within the
# per-client index pool) so client training can be vmapped.
# ---------------------------------------------------------------------------


def partition_iid(key: jax.Array, ds: Dataset, n_clients: int, shard_size: int) -> Dataset:
    n = ds.x.shape[0]
    idx = jax.random.randint(key, (n_clients, shard_size), 0, n)
    return Dataset(x=ds.x[idx], y=ds.y[idx])  # (n_clients, shard, ...)


def partition_dirichlet(
    key: jax.Array, ds: Dataset, n_clients: int, shard_size: int, alpha: float = 0.1,
    n_classes: int = 10,
) -> Dataset:
    """Heterogeneous allocation: each client's class mix ~ Dirichlet(alpha)."""
    np_rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    y = np.asarray(ds.y)
    by_class = [np.nonzero(y == c)[0] for c in range(n_classes)]
    xs, ys = [], []
    for i in range(n_clients):
        probs = np_rng.dirichlet(alpha * np.ones(n_classes))
        # guard against empty classes
        probs = np.array([p if len(by_class[c]) else 0.0 for c, p in enumerate(probs)])
        probs = probs / probs.sum()
        counts = np_rng.multinomial(shard_size, probs)
        sel = np.concatenate(
            [np_rng.choice(by_class[c], size=k, replace=True) for c, k in enumerate(counts) if k > 0]
        )
        np_rng.shuffle(sel)
        xs.append(np.asarray(ds.x)[sel])
        ys.append(y[sel])
    return Dataset(x=jnp.asarray(np.stack(xs)), y=jnp.asarray(np.stack(ys)))
