"""Roofline-term derivation from a compiled (dry-run) artifact.

Three terms, each in seconds (TPU v5e constants from ``mesh.py``):

    compute    = HLO_FLOPs / (chips * 197 TFLOP/s)
    memory     = HLO_bytes / (chips * 819 GB/s)
    collective = collective_bytes / (chips * 50 GB/s/link)

``compiled.cost_analysis()`` supplies per-device FLOPs and bytes accessed;
collective bytes are parsed out of the optimized HLO text by summing the
*output* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (output size == bytes placed on
the wire per device for AG/AR-bidirectional convention; we use it uniformly
and document the convention in EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

from .mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# one shape literal, e.g.  bf16[16,4096,7168]  or  f32[] (scalar)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# an HLO instruction line:   %name = <shape or tuple> opcode(
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+([\w\-]+)\(")


def _shape_bytes(shape_text: str) -> int:
    """Total bytes of all shape literals in a (possibly tuple) shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_text, opcode = m.groups()
        kind = next((k for k in COLLECTIVE_KINDS
                     if opcode == k or opcode.startswith(k + "-")), None)
        if kind is None:
            continue
        b = _shape_bytes(shape_text)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    flops: float                   # per device
    hbm_bytes: float               # per device
    collective_bytes: float        # per device
    chips: int
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.flops / PEAK_FLOPS_BF16
        self.memory_s = self.hbm_bytes / HBM_BW
        self.collective_s = self.collective_bytes / ICI_BW_PER_LINK

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect-overlap) step-time bound: max of the terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> Dict[str, float]:
        return {
            "flops_per_dev": self.flops, "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.collective_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "bound_s": self.step_time_s,
        }


def analyze(compiled, mesh) -> Dict:
    """All roofline-relevant numbers from one compiled executable.

    FLOPs/bytes come from the hierarchical HLO cost model (hlo_cost.py),
    which multiplies while-loop bodies by their known trip counts --
    ``compiled.cost_analysis()`` counts each loop body exactly once and
    underestimates scanned-layer programs by orders of magnitude (verified
    in tests/test_hlo_cost.py).  cost_analysis values are kept alongside
    for reference.
    """
    from . import hlo_cost
    chips = mesh.devices.size
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    text = compiled.as_text()
    totals = hlo_cost.analyze_text(text)
    coll = CollectiveStats(
        bytes_by_kind={k: int(v) for k, v in totals.coll_bytes.items()},
        count_by_kind={k: int(v) for k, v in totals.coll_count.items()})
    rl = Roofline(flops=totals.flops, hbm_bytes=totals.hbm_bytes,
                  collective_bytes=float(coll.total_bytes), chips=chips)
    mem = compiled.memory_analysis()
    memory = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
        + (getattr(mem, "temp_size_in_bytes", 0) or 0),
    }
    return {"roofline": rl, "collectives": coll, "memory": memory,
            "cost": dict(cost),
            "xla_cost_flops_once": float(cost.get("flops", 0.0))}
