"""Deterministic stand-in for `hypothesis` when it isn't installed.

The container image has no `hypothesis`; instead of skipping the whole
property-test modules we replace `given/settings/st` with a tiny fixed-seed
sampler: each strategy contributes its range endpoints, midpoint, and a few
seeded uniform draws, and the decorated test body runs once per sampled
combination.  No shrinking, no database -- just deterministic coverage of
the same parameter ranges.  With `hypothesis` installed the real library is
used (see the try/except import in the test modules).
"""
from __future__ import annotations

import functools
import random
import zlib

N_SAMPLES = 8


def _seed(*parts) -> int:
    # hash() is salted per process (PYTHONHASHSEED); crc32 of the repr keeps
    # the sampled inputs identical across runs, as "deterministic" promises.
    return zlib.crc32(repr(parts).encode())


class _Strategy:
    def __init__(self, values):
        self.values = list(values)


class st:  # mirrors `hypothesis.strategies` for the subset the tests use
    @staticmethod
    def floats(min_value, max_value):
        rnd = random.Random(_seed("floats", min_value, max_value))
        vals = [min_value, max_value, 0.5 * (min_value + max_value)]
        vals += [min_value + (max_value - min_value) * rnd.random()
                 for _ in range(N_SAMPLES - len(vals))]
        return _Strategy(vals)

    @staticmethod
    def integers(min_value, max_value):
        rnd = random.Random(_seed("integers", min_value, max_value))
        vals = {min_value, max_value, (min_value + max_value) // 2}
        while len(vals) < min(N_SAMPLES, max_value - min_value + 1):
            vals.add(rnd.randint(min_value, max_value))
        return _Strategy(sorted(vals))


def given(*strategies):
    """Run the test once per sampled combination (zip of rotated samples)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):  # args = (self,) for method tests
            for k in range(N_SAMPLES):
                combo = tuple(s.values[(k + 3 * i) % len(s.values)]
                              for i, s in enumerate(strategies))
                fn(*args, *combo, **kwargs)
        # pytest introspects signatures through __wrapped__ and would treat
        # the sampled parameters as fixtures; hide the original signature.
        del wrapper.__wrapped__
        return wrapper

    return deco


def settings(**kwargs):  # max_examples / deadline are meaningless here
    def deco(fn):
        return fn

    return deco
