"""Example: lower one (arch x shape) pair on the production mesh and print
its roofline decomposition -- the programmatic version of
``python -m repro.launch.dryrun``.

    PYTHONPATH=src python examples/multi_arch_dryrun.py --arch jamba-v0.1-52b \
        --shape decode_32k [--multi-pod]
"""
import argparse
import json

# must run before any other jax-touching import (device-count lock-in)
from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS at import)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-v0.1-52b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    res = dryrun.run_combo(args.arch, args.shape, multi_pod=args.multi_pod)
    print(json.dumps(res, indent=2, default=str))


if __name__ == "__main__":
    main()
