"""Production mesh construction.

Defined as functions (not module constants) so importing this module never
touches jax device state -- the dry-run must set XLA_FLAGS *before* the
first jax initialization.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU v5e production mesh: one pod = (16, 16); two pods = (2, 16, 16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Degenerate 1x1 mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s per link
