"""Pallas TPU kernel: per-block Bernoulli KL reduction.

Adaptive(-Avg) block allocation needs  sum_{e in block} d_KL(q_e || p_e)
every round for every block (a d-sized elementwise + reduce).  This is a
VPU-bound streaming reduction: (1, TILE_S) tiles of q and p flow through
VMEM; the scalar per-block partial sums accumulate in the output block
across the S-grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_S = 512
_EPS = 1e-6


def _kl_kernel(q_ref, p_ref, o_ref):
    s = pl.program_id(1)
    q = jnp.clip(q_ref[0], _EPS, 1.0 - _EPS)
    p = jnp.clip(p_ref[0], _EPS, 1.0 - _EPS)
    kl = q * (jnp.log(q) - jnp.log(p)) + (1.0 - q) * (jnp.log1p(-q) - jnp.log1p(-p))
    part = jnp.sum(kl)

    @pl.when(s == 0)
    def _init():
        o_ref[0] = part

    @pl.when(s != 0)
    def _acc():
        o_ref[0] = o_ref[0] + part


@functools.partial(jax.jit, static_argnames=("interpret",))
def bernoulli_kl_pallas(q: jax.Array, p: jax.Array, *, interpret: bool = True):
    """Per-block KL sums for (NB, S) with S % TILE_S == 0; returns (NB,)."""
    nb, s = q.shape
    if s % TILE_S != 0:
        raise ValueError(
            f"bernoulli_kl_pallas needs S % {TILE_S} == 0, got S={s} "
            "(use ops.bernoulli_kl for the padded general-shape entry point)")
    grid = (nb, s // TILE_S)
    return pl.pallas_call(
        _kl_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TILE_S), lambda b_, s_: (b_, s_)),
            pl.BlockSpec((1, TILE_S), lambda b_, s_: (b_, s_)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b_, s_: (b_,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.float32),
        interpret=interpret,
    )(q, p)
