"""Minimal Random Coding (MRC) with shared randomness -- the paper's C_mrc.

Two parties hold a common *prior* P (Bernoulli parameter vector) and shared
randomness (a counter-based PRNG key).  The encoder additionally holds a
*posterior* Q and wants the decoder to obtain a sample ~Q.  Both sides derive
the same ``n_is`` candidates X_1..X_{n_is} ~ P; the encoder forms the
importance distribution

    W(i) proportional to Q(X_i) / P(X_i)

samples an index I ~ W (Gumbel-max) and transmits only I  --  log2(n_is) bits.

The model vector of dimension d is partitioned into B blocks; MRC runs
independently per block (the paper's "B blocks of size d/B"), so the uplink
cost is B * log2(n_is) bits per conveyed sample.

Two codec paths are provided:

* **fixed blocks** (`encode_fixed` / `decode_fixed`): all blocks have the same
  static size.  Candidates are derived per (block, row) with
  ``fold_in(fold_in(key, block), row)`` so the *decoder regenerates only the
  selected row* -- decode is O(d), not O(d * n_is).  The importance-weight
  evaluation is the matvec ``logW = X @ a + sum(b)`` (see
  ``core.bernoulli.log_ratio_coeffs``) and can be routed through the Pallas
  TPU kernel in ``repro.kernels``.

* **segments** (`encode_segments` / `decode_segments`): variable-size blocks
  described by a segment-id vector, used by the Adaptive allocation of Isik
  et al. (2024).  The weight evaluation is pluggable via ``seg_logw_fn``:
  the jnp default materialises the (n_is, d) candidate tensor; the Pallas
  segment-logW kernel (``repro.kernels.ops.segment_logw_fn``) streams it
  through VMEM instead.  ``seg_ids`` must be non-decreasing starting at 0
  (the wire plan header is run-length coded); the codec boundary validates
  this whenever the vector is concrete.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .bernoulli import clip01, log_ratio_coeffs

# ---------------------------------------------------------------------------
# Key derivation (the "shared randomness" of the paper, threefry counters).
# ---------------------------------------------------------------------------


def round_key(base: jax.Array, t) -> jax.Array:
    """Shared key for global round t."""
    return jax.random.fold_in(base, t)


def client_key(base: jax.Array, client_id) -> jax.Array:
    """Private shared randomness between the federator and one client."""
    return jax.random.fold_in(jax.random.fold_in(base, 0x5EED), client_id)


def sample_key(base: jax.Array, ell) -> jax.Array:
    """Per conveyed-sample (ell in [n_UL] or [n_DL]) candidate key."""
    return jax.random.fold_in(base, ell)


def _block_candidates(shared_key: jax.Array, block_id, n_is: int, size: int) -> jax.Array:
    """All n_is candidate uniform rows for one block: (n_is, size).

    One threefry stream per block (cheap); both sides derive the identical
    tensor, which is all the shared-randomness assumption requires.
    """
    return jax.random.uniform(jax.random.fold_in(shared_key, block_id), (n_is, size))


def _selected_candidate(shared_key: jax.Array, block_id, row, n_is: int, size: int) -> jax.Array:
    """The selected uniform row for one block: (size,)."""
    u = _block_candidates(shared_key, block_id, n_is, size)
    return jax.lax.dynamic_index_in_dim(u, row, axis=0, keepdims=False)


# ---------------------------------------------------------------------------
# Fixed-size block codec.
# ---------------------------------------------------------------------------

LogWFn = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]
# signature: (X: (nb, n_is, S) {0,1}, a: (nb, S), b: (nb, S)) -> (nb, n_is)


def default_logw(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Pure-jnp importance log-weights: logW = X @ a + sum(b)."""
    return jnp.einsum("bis,bs->bi", x, a) + jnp.sum(b, axis=-1, keepdims=True)


class MRCResult(NamedTuple):
    indices: jax.Array  # (B,) int32 -- what actually goes over the wire
    sample: jax.Array   # (B, S) {0,1} -- decoder-side reconstruction


@functools.partial(jax.jit, static_argnames=("n_is", "chunk", "logw_fn"))
def encode_fixed(
    shared_key: jax.Array,
    select_key: jax.Array,
    q: jax.Array,
    p: jax.Array,
    *,
    n_is: int,
    chunk: int = 32,
    logw_fn: Optional[LogWFn] = None,
) -> MRCResult:
    """MRC-encode posterior q against prior p, both (B, S) block matrices.

    Returns the transmitted indices and the sample the decoder will see
    (identical to what `decode_fixed` reconstructs from the indices).
    """
    logw_impl = logw_fn if logw_fn is not None else default_logw
    B, S = q.shape
    nb = min(chunk, B)
    n_chunks = -(-B // nb)
    pad = n_chunks * nb - B
    if pad:
        # Padding blocks carry q == p == 0.5: zero KL, index discarded later.
        halfq = jnp.full((pad, S), 0.5, q.dtype)
        q = jnp.concatenate([q, halfq])
        p = jnp.concatenate([p, halfq])

    a, b = log_ratio_coeffs(q, p)  # (B', S) each

    def chunk_body(c):
        block_ids = c * nb + jnp.arange(nb)
        pc = jax.lax.dynamic_slice_in_dim(p, c * nb, nb, axis=0)  # (nb, S)
        ac = jax.lax.dynamic_slice_in_dim(a, c * nb, nb, axis=0)
        bc = jax.lax.dynamic_slice_in_dim(b, c * nb, nb, axis=0)
        u = jax.vmap(lambda bid: _block_candidates(shared_key, bid, n_is, S))(block_ids)
        x = (u < clip01(pc)[:, None, :]).astype(jnp.float32)
        logw = logw_impl(x, ac, bc)  # (nb, n_is)
        gu = jax.vmap(
            lambda bid: jax.random.uniform(jax.random.fold_in(select_key, bid), (n_is,))
        )(block_ids)
        gumbel = -jnp.log(-jnp.log(jnp.clip(gu, 1e-12, 1.0 - 1e-12)))
        idx = jnp.argmax(logw + gumbel, axis=-1).astype(jnp.int32)  # (nb,)
        chosen = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0, :]  # (nb, S)
        return idx, chosen

    idxs, chosen = jax.lax.map(chunk_body, jnp.arange(n_chunks))
    idxs = idxs.reshape(-1)[:B]
    chosen = chosen.reshape(-1, S)[:B]
    return MRCResult(indices=idxs, sample=chosen)


@functools.partial(jax.jit, static_argnames=("n_is",))
def decode_fixed(shared_key: jax.Array, indices: jax.Array, p: jax.Array, *, n_is: int) -> jax.Array:
    """Reconstruct the encoder-selected sample from the indices: (B, S)."""
    B, S = p.shape

    def per_block(bid, idx, pb):
        u = _selected_candidate(shared_key, bid, idx, n_is, S)
        return (u < clip01(pb)).astype(jnp.float32)

    return jax.vmap(per_block)(jnp.arange(B), indices, p)


def transmit_fixed(
    shared_key: jax.Array,
    select_key: jax.Array,
    q: jax.Array,
    p: jax.Array,
    *,
    n_is: int,
    n_samples: int = 1,
    chunk: int = 32,
    logw_fn: Optional[LogWFn] = None,
):
    """Convey ``n_samples`` i.i.d. MRC samples of q (fresh candidates per ell).

    Returns (indices (n_samples, B), mean_sample (B, S)). ``mean_sample`` is
    the decoder-side estimate  q_hat = 1/n_samples * sum_ell x_ell .
    """
    def one(ell):
        res = encode_fixed(
            sample_key(shared_key, ell),
            sample_key(select_key, ell),
            q,
            p,
            n_is=n_is,
            chunk=chunk,
            logw_fn=logw_fn,
        )
        return res.indices, res.sample

    idxs, samples = jax.lax.map(one, jnp.arange(n_samples))
    return idxs, jnp.mean(samples, axis=0)


def receive_fixed(shared_key: jax.Array, indices: jax.Array, p: jax.Array, *, n_is: int) -> jax.Array:
    """Decode n_samples relayed index vectors: indices (n_samples, B) -> (B, S)."""
    samples = jax.vmap(
        lambda ell, idx: decode_fixed(sample_key(shared_key, ell), idx, p, n_is=n_is)
    )(jnp.arange(indices.shape[0]), indices)
    return jnp.mean(samples, axis=0)


# ---------------------------------------------------------------------------
# Variable-size (segment) codec for Adaptive block allocation.
# ---------------------------------------------------------------------------


def _segment_candidates(shared_key: jax.Array, n_is: int, d: int) -> jax.Array:
    rows = jnp.arange(n_is)
    return jax.vmap(lambda r: jax.random.uniform(jax.random.fold_in(shared_key, r), (d,)))(rows)


SegLogWFn = Callable[[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array, int], jax.Array]
# signature: (u: (n_is, d) uniforms, p: (d,) clipped prior, a: (d,),
#             b: (d,), seg_ids: (d,), n_seg) -> (n_is, n_seg)


def default_segment_logw(u: jax.Array, p: jax.Array, a: jax.Array,
                         b: jax.Array, seg_ids: jax.Array, n_seg: int) -> jax.Array:
    """Pure-jnp segment log-weights: vmapped segment_sum over the fused
    compare+select ``where(u < p, a, 0)`` (materialises (n_is, d) in HBM;
    the Pallas route in ``repro.kernels.ops.segment_logw`` does not)."""
    xa = jnp.where(u < p[None, :], a[None, :], 0.0)             # (n_is, d)
    seg_sum = lambda row: jax.ops.segment_sum(row, seg_ids, num_segments=n_seg)
    return jax.vmap(seg_sum)(xa) + seg_sum(b)[None, :]          # (n_is, n_seg)


def _validate_seg_ids(seg_ids) -> None:
    """Host-side check of the segment-codec contract.

    The wire block-plan header (``wire.codecs.put_plan_segments``) encodes a
    segmentation as run-lengths, so a permuted ``seg_ids`` would round-trip
    the header to a *different* segmentation and decode a wrong sample with
    no error.  Enforce non-decreasing ids starting at 0 whenever the vector
    is concrete; traced ``seg_ids`` (the fused engine's bucketed plans, which
    are cumsum-built and monotone by construction) skip the check.
    """
    if isinstance(seg_ids, jax.core.Tracer):
        return
    seg = np.asarray(seg_ids)
    if seg.ndim != 1 or seg.size == 0:
        raise ValueError(
            f"seg_ids must be a non-empty 1-D vector, got shape {seg.shape}")
    if int(seg[0]) != 0 or np.any(np.diff(seg) < 0):
        raise ValueError(
            "seg_ids must be non-decreasing and start at 0: the wire plan "
            "header stores segments as run-lengths, so any other ordering "
            "round-trips to a different segmentation")


@functools.partial(jax.jit, static_argnames=("n_is", "n_seg", "seg_logw_fn"))
def _encode_segments(
    shared_key: jax.Array,
    select_key: jax.Array,
    q: jax.Array,
    p: jax.Array,
    seg_ids: jax.Array,
    *,
    n_is: int,
    n_seg: int,
    seg_logw_fn: Optional[SegLogWFn] = None,
) -> MRCResult:
    logw_impl = seg_logw_fn if seg_logw_fn is not None else default_segment_logw
    pc = clip01(p)
    u = _segment_candidates(shared_key, n_is, q.shape[0])       # (n_is, d)
    a, b = log_ratio_coeffs(q, p)                               # (d,), (d,)
    logw = logw_impl(u, pc, a, b, seg_ids, n_seg)               # (n_is, n_seg)
    gu = jax.random.uniform(select_key, (n_is, n_seg))
    gumbel = -jnp.log(-jnp.log(jnp.clip(gu, 1e-12, 1.0 - 1e-12)))
    idx = jnp.argmax(logw + gumbel, axis=0).astype(jnp.int32)   # (n_seg,)
    u_sel = jnp.take_along_axis(u, idx[seg_ids][None, :], axis=0)[0]  # (d,)
    chosen = (u_sel < pc).astype(jnp.float32)
    return MRCResult(indices=idx, sample=chosen)


def encode_segments(
    shared_key: jax.Array,
    select_key: jax.Array,
    q: jax.Array,
    p: jax.Array,
    seg_ids: jax.Array,
    *,
    n_is: int,
    n_seg: int,
    seg_logw_fn: Optional[SegLogWFn] = None,
) -> MRCResult:
    """MRC over variable blocks given per-parameter segment ids (d,).

    The importance weights decompose as  logW(i, s) = sum_{e in s} x_ie*a_e
    + sum_{e in s} b_e : the prior term is candidate-independent, so it is
    segment-summed once ((d,) -> (n_seg,)) instead of being broadcast into
    an (n_is, d) add, and the candidate term streams through one fused
    compare+select pass over the uniforms (``where(u < p, a, 0)`` -- exact:
    x is {0, 1} and a is finite after clipping).  The selected sample is
    re-thresholded from the chosen candidate *row* only, never from a
    materialised (n_is, d) sample tensor.  This is the fused adaptive
    path's per-round hot loop (every client, every sample).

    ``seg_logw_fn`` makes the weight evaluation pluggable the way
    ``logw_fn`` is for ``encode_fixed``: pass
    ``repro.kernels.ops.segment_logw_fn()`` to route it through the Pallas
    segment-logW kernel (streams u once, never materialises (n_is, d)).
    It is a static jit argument hashed by identity -- hand in a cached
    closure, not a fresh lambda per call.
    """
    _validate_seg_ids(seg_ids)
    return _encode_segments(shared_key, select_key, q, p, seg_ids,
                            n_is=n_is, n_seg=n_seg, seg_logw_fn=seg_logw_fn)


@functools.partial(jax.jit, static_argnames=("n_is",))
def _decode_segments(
    shared_key: jax.Array, indices: jax.Array, p: jax.Array, seg_ids: jax.Array, *, n_is: int
) -> jax.Array:
    d = p.shape[0]
    u = _segment_candidates(shared_key, n_is, d)
    u_sel = jnp.take_along_axis(u, indices[seg_ids][None, :], axis=0)[0]
    return (u_sel < clip01(p)).astype(jnp.float32)


def decode_segments(
    shared_key: jax.Array, indices: jax.Array, p: jax.Array, seg_ids: jax.Array, *, n_is: int
) -> jax.Array:
    """Reconstruct the encoder-selected sample from segment indices: (d,)."""
    _validate_seg_ids(seg_ids)
    return _decode_segments(shared_key, indices, p, seg_ids, n_is=n_is)


def receive_segments(
    shared_key: jax.Array, indices: jax.Array, p: jax.Array, seg_ids: jax.Array, *, n_is: int
) -> jax.Array:
    """Decode n_samples relayed segment-index vectors: (n_samples, n_seg) -> (d,)."""
    samples = jax.vmap(
        lambda ell, idx: decode_segments(sample_key(shared_key, ell), idx, p, seg_ids, n_is=n_is)
    )(jnp.arange(indices.shape[0]), indices)
    return jnp.mean(samples, axis=0)


def transmit_segments(
    shared_key, select_key, q, p, seg_ids, *, n_is: int, n_seg: int,
    n_samples: int = 1, seg_logw_fn: Optional[SegLogWFn] = None,
):
    def one(ell):
        res = encode_segments(
            sample_key(shared_key, ell), sample_key(select_key, ell), q, p, seg_ids,
            n_is=n_is, n_seg=n_seg, seg_logw_fn=seg_logw_fn,
        )
        return res.indices, res.sample

    idxs, samples = jax.lax.map(one, jnp.arange(n_samples))
    return idxs, jnp.mean(samples, axis=0)
