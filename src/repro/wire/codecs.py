"""Payload codecs for every channel family (cf. DESIGN.md "Wire format").

Each codec writes *exactly* the bits its channel books in the BitMeter:

* **MRC index streams** -- one ``ceil(log2(n_is))``-bit field per conveyed
  sample per (billable) block.  Registry schemes use power-of-two ``n_is``,
  so the codec width equals the booked ``log2(n_is)`` exactly; a
  non-power-of-two ``n_is`` books fractional bits no integer codec can
  meet and is rejected loudly.
* **Block-plan headers** -- AdaptiveAvg: the pow2 size exponent in
  ``ceil(log2(max_block))`` bits.  Adaptive (segment) plans: one
  ``(length - 1)`` field of ``ceil(log2(max_block))`` bits per billable
  segment, exactly the ``billable * ceil(log2(max_block))`` overhead the
  allocation books; segments longer than ``max_block`` cannot be
  represented at the booked rate and raise :class:`WireCapacityError`.
* **Sign payloads** -- per compression pass: one f32 scale + a d-bit sign
  bitmap (``v >= 0``), i.e. the ``d + 32`` bits/pass the EF channels book.
* **Top-k records** -- per kept entry: a ``ceil(log2(d))``-bit index + an
  f32 value, matching ``quantizers.topk_bits``.
* **Dense payloads** -- raw big-endian f32, 32 bits/value.

All functions take/return numpy arrays; float round-trips are bit-exact.
"""
from __future__ import annotations

import math

import numpy as np

from .bitio import BitReader, BitWriter, WireFormatError


class WireCapacityError(WireFormatError):
    """A value cannot be represented at the booked field width."""


# ---------------------------------------------------------------------------
# MRC index streams.
# ---------------------------------------------------------------------------


def index_width(n_is: int) -> int:
    """Bits per MRC index; must equal the booked log2(n_is) exactly."""
    w = math.ceil(math.log2(n_is))
    if 2 ** w != n_is:
        raise WireCapacityError(
            f"n_is={n_is} books fractional bits per index "
            f"(log2={math.log2(n_is):.4f}); wire codecs need a power of two")
    return w


def put_indices(w: BitWriter, indices, n_is: int) -> None:
    """Write an index array (any shape) row-major at index_width bits each."""
    width = index_width(n_is)
    for v in np.asarray(indices, dtype=np.int64).reshape(-1):
        w.write(int(v), width)


def get_indices(r: BitReader, shape, n_is: int) -> np.ndarray:
    width = index_width(n_is)
    count = int(np.prod(shape))
    out = np.empty(count, dtype=np.int32)
    for i in range(count):
        out[i] = r.read(width)
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# Block-plan headers (the allocation side information).
# ---------------------------------------------------------------------------


def _plan_field_width(max_block: int) -> int:
    return math.ceil(math.log2(max_block))


def put_plan_avg(w: BitWriter, size: int, max_block: int) -> None:
    """AdaptiveAvg header: the pow2 block-size exponent."""
    k = int(math.log2(size))
    if 2 ** k != size:
        raise WireCapacityError(f"block size {size} is not a power of two")
    w.write(k, _plan_field_width(max_block))


def get_plan_avg(r: BitReader, max_block: int) -> int:
    return 2 ** r.read(_plan_field_width(max_block))


def put_plan_segments(w: BitWriter, seg_ids, max_block: int) -> None:
    """Adaptive header: per-segment ``length - 1`` fields.

    ``seg_ids`` must be the plan's non-decreasing per-parameter segment-id
    vector; every id in ``0..max`` occurs (duplicate bin edges collapse),
    so each length is >= 1 and ``length - 1`` fits ``ceil(log2(max_block))``
    bits iff the segment is no longer than ``max_block``.
    """
    seg = np.asarray(seg_ids, dtype=np.int64)
    if seg.size and (seg[0] != 0 or np.any(np.diff(seg) < 0)):
        raise WireFormatError(
            "plan seg_ids must be non-decreasing starting at 0: the header "
            "stores run-lengths, so any other ordering would round-trip to "
            "a different segmentation")
    lengths = np.bincount(seg, minlength=int(seg.max()) + 1)
    width = _plan_field_width(max_block)
    if np.any(lengths < 1):
        raise WireFormatError("empty segment in plan header")
    if np.any(lengths > max_block):
        raise WireCapacityError(
            f"segment of {int(lengths.max())} params exceeds max_block="
            f"{max_block}; the booked {width}-bit boundary fields cannot "
            "represent it")
    for ln in lengths:
        w.write(int(ln) - 1, width)


def get_plan_segments(r: BitReader, d: int, max_block: int) -> np.ndarray:
    """Read segment lengths until they tile [0, d); self-delimiting since
    every length is >= 1 and the lengths sum to exactly d."""
    width = _plan_field_width(max_block)
    lengths = []
    total = 0
    while total < d:
        ln = r.read(width) + 1
        lengths.append(ln)
        total += ln
    if total != d:
        raise WireFormatError(
            f"plan header lengths sum to {total}, expected {d}")
    return np.repeat(np.arange(len(lengths), dtype=np.int32),
                     np.asarray(lengths, dtype=np.int64))


# ---------------------------------------------------------------------------
# Sign / top-k / dense payloads.
# ---------------------------------------------------------------------------


def put_bitmap(w: BitWriter, bools) -> None:
    """Write a boolean vector as an MSB-first bitmap, 1 bit per entry."""
    arr = np.asarray(bools, dtype=bool).reshape(-1)
    w.write_bits(np.packbits(arr).tobytes(), arr.size)


def get_bitmap(r: BitReader, n: int) -> np.ndarray:
    data, _ = r.read_payload(n)
    return np.unpackbits(np.frombuffer(data, np.uint8), count=n).astype(bool)


def put_sign_pass(w: BitWriter, scale, signs) -> None:
    """One sign-EF compression pass: f32 scale + d-bit sign bitmap."""
    w.write_f32(scale)
    put_bitmap(w, signs)


def get_sign_pass(r: BitReader, d: int):
    scale = r.read_f32()
    return scale, get_bitmap(r, d)


def topk_index_width(d: int) -> int:
    return math.ceil(math.log2(max(d, 2)))  # matches quantizers.topk_bits


def put_topk(w: BitWriter, indices, values, d: int) -> None:
    iw = topk_index_width(d)
    idx = np.asarray(indices, dtype=np.int64).reshape(-1)
    val = np.asarray(values, dtype=np.float32).reshape(-1)
    if idx.shape != val.shape:
        raise WireFormatError("top-k index/value shape mismatch")
    for i, v in zip(idx, val):
        w.write(int(i), iw)
        w.write(int(np.float32(v).view(np.uint32)), 32)


def get_topk(r: BitReader, k: int, d: int):
    iw = topk_index_width(d)
    idx = np.empty(k, dtype=np.int32)
    val = np.empty(k, dtype=np.uint32)
    for i in range(k):
        idx[i] = r.read(iw)
        val[i] = r.read(32)
    return idx, val.view(np.float32)


def put_dense(w: BitWriter, values) -> None:
    w.write_f32_array(values)


def get_dense(r: BitReader, n: int) -> np.ndarray:
    return r.read_f32_array(n)
