"""Hierarchical HLO cost model: trip-count multiplication correctness.

Also documents the motivating defect: XLA's cost_analysis() counts a while
body exactly once, so any scanned/looped program needs this model.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import HloCostModel, analyze_text


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile()


SDS = jax.ShapeDtypeStruct


def test_xla_cost_analysis_counts_loop_once():
    """The defect this module works around (if this fails, XLA was fixed
    and the correction may be removable)."""
    def f(x, w):
        out, _ = jax.lax.scan(lambda c, _: (c @ w, ()), x, None, length=16)
        return out
    c = _compile(f, SDS((64, 64), jnp.float32), SDS((64, 64), jnp.float32))
    ca = c.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert float(ca.get("flops", 0)) < 2 * 64 ** 3 * 16 / 2  # << K x matmul


@pytest.mark.parametrize("k", [1, 4, 16, 60])
def test_scan_flops_scale_with_trip_count(k):
    def f(x, w):
        out, _ = jax.lax.scan(lambda c, _: (c @ w, ()), x, None, length=k)
        return out
    c = _compile(f, SDS((64, 64), jnp.float32), SDS((64, 64), jnp.float32))
    t = analyze_text(c.as_text())
    expect = 2 * 64 ** 3 * k
    assert abs(t.flops - expect) / expect < 0.05, (t.flops, expect)


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            c2, _ = jax.lax.scan(lambda ci, __: (ci @ w, ()), c, None, length=5)
            return c2, ()
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out
    c = _compile(f, SDS((32, 32), jnp.float32), SDS((32, 32), jnp.float32))
    t = analyze_text(c.as_text())
    expect = 2 * 32 ** 3 * 15
    assert abs(t.flops - expect) / expect < 0.05


def test_remat_grad_flops_ratio():
    """checkpointed scan backward ~= 4x forward FLOPs (fwd + remat + 2x bwd)."""
    def f(params, x):
        def body(c, w):
            return jnp.tanh(c @ w), ()
        def loss(params, x):
            out, _ = jax.lax.scan(jax.checkpoint(body), x, params)
            return jnp.sum(out ** 2)
        return jax.grad(loss)(params, x)
    c = _compile(f, SDS((8, 64, 64), jnp.float32), SDS((64, 64), jnp.float32))
    t = analyze_text(c.as_text())
    fwd = 2 * 64 ** 3 * 8
    assert 2.5 < t.flops / fwd < 5.0, t.flops / fwd


def test_hbm_bytes_nonzero_and_scale():
    def f(x):
        out, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c), ()), x, None, length=10)
        return out
    c = _compile(f, SDS((1024, 1024), jnp.float32))
    t = analyze_text(c.as_text())
    # >= 10 iterations x (read + write) of 4MB
    assert t.hbm_bytes >= 10 * 2 * 4 * 1024 * 1024 * 0.5


def test_collectives_inside_loops_multiplied():
    import os
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device (run under dryrun env)")


def test_entry_detected():
    def f(x):
        return x + 1
    c = _compile(f, SDS((8,), jnp.float32))
    m = HloCostModel(c.as_text())
    assert m.entry is not None
    assert m.entry_cost().hbm_bytes > 0
