"""Composable communication channels for the FL engine (cf. DESIGN.md).

BICompFL's central observation is that uplink and downlink are *both*
compression channels whose costs interact.  This module makes each direction
a first-class object: a :class:`Channel` encodes what one party sends, what
the other party reconstructs, and **how many bits crossed the wire** -- the
bit accounting lives in the channel, not in the training loop.

Functional core
---------------
Every channel is a *pure* function over an explicit state pytree, so the
engine can run the whole multi-round loop as one ``jax.lax.scan`` (the
device-resident fused path, cf. ``engine.FLEngine``):

Uplink channels implement::

    step_up(ctx, state, payload, priors) -> (server_side_estimates, bits, state)

where ``payload`` is the per-active-client message source -- Bernoulli
posteriors ``q`` for the probabilistic-mask path, weight deltas for
conventional FL -- and ``priors`` are the clients' current global-model
estimates (the MRC prior; ignored by the non-stochastic compressors).

Downlink channels implement::

    step_down(ctx, state, update, theta, theta_hat) -> (DownlinkResult, state)

receiving the aggregator's proposed :class:`ServerUpdate` and returning the
*final* server model, the new per-client estimates and the downlink bits.
The downlink owns the final model update because some schemes (sign-EF a la
DoubleSqueeze) have the server itself step with the *compressed* aggregate.

State is any pytree: ``()`` for stateless channels, the error-feedback
memory array for the EF compressors.  ``init_up_state(n, d)`` /
``init_down_state(n, d)`` build the initial state;
``flush_step(state, n, d) -> (residual, bits, state)`` implements the
periodic error-reset of CSER / LIEC.

Bits contract
-------------
``bits`` return values are computed from static shapes and the round's
:class:`BlockPlan`.  Under a *static* plan that makes them plain Python
floats, which lets the fused engine book communication host-side with zero
device syncs.  Under a bucketed adaptive plan (built on device inside the
fused scan body) ``plan.billable`` is a **traced** block count, so ``bits``
becomes a traced f32 scalar; the engine then carries per-round bits through
the scan outputs and books them into the BitMeter after the run.  Channels
must always bill ``plan.billable`` (never ``plan.n_blocks``, which is only
the static segment *capacity*) and must keep the bits expression otherwise
shape-derived, so both representations stay exact.

Object shell
------------
The pre-existing stateful API (``transmit`` / ``distribute`` / ``flush`` /
``reset``) is a thin wrapper over the functional core: the shell owns the
state pytree and threads it through the pure steps.  Instantiate a fresh
channel per run (or ``reset()`` it) exactly as before.

Key-derivation tags reproduce the seed loops exactly, so the engine is
bit-for-bit compatible with the original ``run_bicompfl`` (see
tests/test_engine_parity.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrc
from repro.core.bernoulli import clip01
from repro.core.blocks import BlockPlan  # noqa: F401  (re-export: the plan
                                         # travels with the channel API)
from repro.core.quantizers import (FLOAT_BITS, sign_compress, topk_bits,
                                   topk_compress)
from repro.wire import (DIR_DOWN, DIR_FLUSH_UP, DIR_UP, SERVER, BitReader,
                        BitWriter, Message)
from repro.wire import codecs as wcodecs

# ---------------------------------------------------------------------------
# Key-derivation tags (shared-randomness schedule, identical to the seed).
# ---------------------------------------------------------------------------

TAG_TRAIN = 1          # per-round local-training keys
TAG_UL_SELECT = 2      # uplink Gumbel selection stream
TAG_DL_SHARED = 3      # downlink candidate stream
TAG_DL_SELECT_COMMON = 4   # downlink selection, common (GR-Reconst)
TAG_DL_SELECT_PRIVATE = 5  # downlink selection, per-client (PR variants)
TAG_COHORT = 6         # jax-native cohort sampling (engine, cohort_rng="jax")

# State pytree of a stateless channel: no leaves, trivially scan-carriable.
EMPTY_STATE: Tuple = ()


def pin(token, x):
    """Pin ``x``'s rounding against re-fusion inside one compiled program.

    The host loop materialises each stage's output between separately
    compiled dispatches; inside the engine's fused scan XLA instead fuses
    values into their consumers and LLVM FMA-contracts chains like
    ``theta - lr * mean(...)`` into a single rounding, breaking bit-parity
    with the host path.  ``optimization_barrier`` is deleted by the CPU
    pipeline and a select on a runtime predicate gets *sunk through* the
    arithmetic, so the robust pin routes the value through integer space:
    ``bitcast_f32->i32 -> add(token) -> bitcast_i32->f32`` where ``token``
    is a *traced* int32 zero (``RoundContext.pin_token``, fed from the scan
    xs so nothing can constant-fold it).  Adding integer zero is the exact
    identity on the bit pattern, and no floating-point rewrite crosses an
    integer op -- the f32 value is forced to its rounded form before any
    consumer sees it.  On the host path ``token`` is None and this is a
    no-op.  Only float32 leaves are touched; other dtypes are exact anyway.
    """
    if token is None:
        return x

    def _pin(v):
        v = jnp.asarray(v)
        if v.dtype != jnp.float32:
            return v
        bits = jax.lax.bitcast_convert_type(v, jnp.int32)
        return jax.lax.bitcast_convert_type(bits + token, jnp.float32)

    return jax.tree.map(_pin, x)


def _vfold(key: jax.Array, ids: jax.Array) -> jax.Array:
    """fold_in(key, i) for every client id i -> stacked keys."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)


def _vclient_keys(kt: jax.Array, ids: jax.Array) -> jax.Array:
    """Per-client private shared randomness, vmapped over ids."""
    return jax.vmap(lambda i: mrc.client_key(kt, i))(ids)


# ---------------------------------------------------------------------------
# Block helpers.  Pad value 0.5 for BOTH q and p => padded entries have zero
# KL and never influence the selected index.  Batched over leading dims.
# ---------------------------------------------------------------------------


def to_blocks(v: jax.Array, size: int) -> jax.Array:
    d = v.shape[-1]
    b = -(-d // size)
    pad = b * size - d
    if pad:
        v = jnp.concatenate([v, jnp.full(v.shape[:-1] + (pad,), 0.5, v.dtype)], axis=-1)
    return v.reshape(v.shape[:-1] + (b, size))


def from_blocks(m: jax.Array, d: int) -> jax.Array:
    return m.reshape(m.shape[:-2] + (-1,))[..., :d]


# ---------------------------------------------------------------------------
# Round context / server update.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RoundContext:
    """Everything a channel may need about the current global round.

    In the fused engine path ``t``, ``key`` and ``active`` are traced scan
    values (``active`` a jnp int vector); channels must only use them in
    traceable positions.  Cohort *size* stays static either way.
    """

    t: Any
    key: jax.Array        # kt = mrc.round_key(base, t) -- shared randomness
    n_clients: int
    d: int
    active: Any           # sorted global ids of the participating cohort
    plan: Optional[BlockPlan] = None
    pin_token: Any = None  # traced int32 zero in the fused path (cf. pin)
    # Aggregation weights over cohort positions under injected faults
    # (repro.fl.faults): 0.0 for dropped / straggling / lost-uplink
    # clients, 1.0 for contributors.  None on fault-free rounds, keeping
    # every aggregator expression bit-identical to the no-faults engine.
    up_weight: Any = None

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def active_ids(self) -> jax.Array:
        return jnp.asarray(self.active, dtype=jnp.int32)


@dataclass(frozen=True)
class ServerUpdate:
    """Aggregator output: the proposed next server model.

    ``delta`` carries the aggregate update direction for delta-space schemes
    (``theta = theta_prev - lr * delta``); it is None for model-space schemes
    (BiCompFL) whose aggregate *is* the new model.
    """

    theta: jax.Array
    delta: Optional[jax.Array] = None
    lr: float = 1.0


class DownlinkResult(NamedTuple):
    theta: jax.Array      # final server model after the downlink
    theta_hat: jax.Array  # (n_clients, d) client estimates
    bits: float


@dataclass(frozen=True)
class WireEnv:
    """Decoder-side context for ``decode_down`` (cf. repro.wire).

    Everything here is information the *receiving* party legitimately holds:
    its own uplink transmission (``up_msgs``, for index-relay downlinks),
    the shared uplink/aggregator definitions, the round's priors, and --
    server-side only -- the aggregator's proposed :class:`ServerUpdate`
    (used where the downlink result's ``theta`` never crosses the wire
    because it stays on the federator).
    """

    uplink: Any
    aggregator: Any
    priors: Any
    up_msgs: Any
    update: ServerUpdate


def _wire_msg(direction: int, sender: int, recipient: int,
              w: BitWriter) -> Message:
    """Seal a finished payload writer into an (unstamped) frame."""
    return Message(direction=direction, sender=int(sender),
                   recipient=int(recipient), payload=w.getvalue(),
                   payload_bits=w.bits_written)


def _wire_reader(m: Message) -> BitReader:
    return BitReader(m.payload, m.payload_bits)


@runtime_checkable
class UplinkChannel(Protocol):
    def init_up_state(self, n: int, d: int): ...

    def step_up(self, ctx: RoundContext, state, payload: jax.Array,
                priors: jax.Array) -> Tuple[jax.Array, float, Any]: ...

    def transmit(self, ctx: RoundContext, payload: jax.Array,
                 priors: jax.Array) -> Tuple[jax.Array, float]: ...


@runtime_checkable
class DownlinkChannel(Protocol):
    broadcast_shareable: bool

    def init_down_state(self, n: int, d: int): ...

    def step_down(self, ctx: RoundContext, state, update: ServerUpdate,
                  theta: jax.Array,
                  theta_hat: jax.Array) -> Tuple[DownlinkResult, Any]: ...

    def distribute(self, ctx: RoundContext, update: ServerUpdate,
                   theta: jax.Array, theta_hat: jax.Array) -> DownlinkResult: ...


# ---------------------------------------------------------------------------
# Shell mixins: the stateful object API over the pure step functions.
# ---------------------------------------------------------------------------


class StatelessUplink:
    """Object shell + trivial state for uplinks without memory."""

    def init_up_state(self, n: int, d: int):
        return EMPTY_STATE

    def export_state(self):
        """Shell-state snapshot (fault-injection carry; trivial here)."""
        return EMPTY_STATE

    def import_state(self, state) -> None:
        pass

    def transmit(self, ctx, payload, priors):
        out, bits, _ = self.step_up(ctx, EMPTY_STATE, payload, priors)
        return out, bits

    def transmit_wire(self, ctx, payload, priors):
        """Like ``transmit`` but also returns the encoded wire messages."""
        out, bits, _, msgs = self.encode_up(ctx, EMPTY_STATE, payload, priors)
        return out, bits, msgs

    def flush_step(self, state, n: int, d: int):
        return 0.0, 0.0, state

    def flush(self, n: int, d: int):
        return 0.0, 0.0

    def flush_wire(self, n: int, d: int):
        r, bits = self.flush(n, d)
        return r, bits, []

    def decode_flush_up(self, msgs, n: int, d: int):
        return 0.0


class StatelessDownlink:
    """Object shell + trivial state for downlinks without memory."""

    # Downlink audience: "all" (every client holds an estimate of the
    # broadcast) or "active" (client-specific payloads for the cohort
    # only).  The engine's fault booking scales per-recipient bits by it.
    downlink_recipients = "all"

    def init_down_state(self, n: int, d: int):
        return EMPTY_STATE

    def export_state(self):
        return EMPTY_STATE

    def import_state(self, state) -> None:
        pass

    def distribute(self, ctx, update, theta, theta_hat):
        res, _ = self.step_down(ctx, EMPTY_STATE, update, theta, theta_hat)
        return res

    def distribute_wire(self, ctx, update, theta, theta_hat, up_msgs):
        res, _, msgs = self.encode_down(ctx, EMPTY_STATE, update, theta,
                                        theta_hat, up_msgs)
        return res, msgs

    def flush_step(self, state, n: int, d: int):
        return 0.0, 0.0, state

    def flush(self, n: int, d: int):
        return 0.0, 0.0


# ---------------------------------------------------------------------------
# MRC channels (the paper's C_mrc, fixed-size blocks / adaptive segments).
# ---------------------------------------------------------------------------


@dataclass
class MRCFixedChannel(StatelessUplink):
    """Uplink MRC over fixed-size blocks, vmapped across the cohort.

    ``shared=True`` (GR) lets every client draw candidates from the *common*
    round key; ``shared=False`` (PR) vmaps over per-client private keys.
    """

    n_is: int = 256
    n_samples: int = 1
    shared: bool = True
    chunk: int = 16
    logw_fn: Any = None

    def _transmit(self, ctx, payload, priors):
        """Shared core: returns (indices, q_hat, bits).  ``step_up`` drops
        the indices (dead code under the fused scan); the wire codec
        serializes them."""
        plan = ctx.plan
        kt = ctx.key
        qb = to_blocks(clip01(payload), plan.size)   # (n_act, B, S)
        pb = to_blocks(clip01(priors), plan.size)
        sels = _vfold(jax.random.fold_in(kt, TAG_UL_SELECT), ctx.active_ids)

        def one(skey, sel, q_i, p_i):
            return mrc.transmit_fixed(
                skey, sel, q_i, p_i, n_is=self.n_is, n_samples=self.n_samples,
                chunk=self.chunk, logw_fn=self.logw_fn)

        if self.shared:
            idxs, q_hat_b = jax.vmap(
                lambda sel, q, p: one(kt, sel, q, p))(sels, qb, pb)
        else:
            skeys = _vclient_keys(kt, ctx.active_ids)
            idxs, q_hat_b = jax.vmap(one)(skeys, sels, qb, pb)
        bits = ctx.n_active * self.n_samples * plan.billable * math.log2(self.n_is)
        return idxs, from_blocks(q_hat_b, ctx.d), bits

    def step_up(self, ctx, state, payload, priors):
        _, q_hat, bits = self._transmit(ctx, payload, priors)
        return q_hat, bits, state

    # -- wire codec --------------------------------------------------------

    def encode_up(self, ctx, state, payload, priors):
        idxs, q_hat, bits = self._transmit(ctx, payload, priors)
        idxs = np.asarray(idxs)  # (n_act, n_samples, B)
        msgs = []
        for j, cid in enumerate(np.asarray(ctx.active)):
            w = BitWriter()
            wcodecs.put_indices(w, idxs[j], self.n_is)
            msgs.append(_wire_msg(DIR_UP, cid, SERVER, w))
        return q_hat, bits, state, msgs

    def decode_up(self, ctx, msgs, priors):
        plan, kt = ctx.plan, ctx.key
        pb = to_blocks(clip01(priors), plan.size)
        shape = (self.n_samples, plan.n_blocks)
        idxs = []
        for m in msgs:
            r = _wire_reader(m)
            idxs.append(wcodecs.get_indices(r, shape, self.n_is))
            r.expect_exhausted()
        idxs = jnp.asarray(np.stack(idxs))
        if self.shared:
            q_hat_b = jax.vmap(lambda idx, p: mrc.receive_fixed(
                kt, idx, p, n_is=self.n_is))(idxs, pb)
        else:
            skeys = _vclient_keys(kt, ctx.active_ids)
            q_hat_b = jax.vmap(lambda k, idx, p: mrc.receive_fixed(
                k, idx, p, n_is=self.n_is))(skeys, idxs, pb)
        return from_blocks(q_hat_b, ctx.d)


@dataclass
class MRCAdaptiveChannel(StatelessUplink):
    """Uplink MRC over variable-size segments (Isik et al. 2024 allocation)."""

    n_is: int = 256
    n_samples: int = 1
    shared: bool = True
    seg_logw_fn: Any = None

    def _transmit(self, ctx, payload, priors):
        plan = ctx.plan
        kt = ctx.key
        seg = jnp.asarray(plan.seg_ids)
        sels = _vfold(jax.random.fold_in(kt, TAG_UL_SELECT), ctx.active_ids)

        def one(skey, sel, q_i, p_i):
            return mrc.transmit_segments(
                skey, sel, q_i, clip01(p_i), seg, n_is=self.n_is,
                n_seg=plan.n_blocks, n_samples=self.n_samples,
                seg_logw_fn=self.seg_logw_fn)

        q = clip01(payload)
        if self.shared:
            idxs, q_hat = jax.vmap(
                lambda sel, q_i, p: one(kt, sel, q_i, p))(sels, q, priors)
        else:
            skeys = _vclient_keys(kt, ctx.active_ids)
            idxs, q_hat = jax.vmap(one)(skeys, sels, q, priors)
        bits = ctx.n_active * self.n_samples * plan.billable * math.log2(self.n_is)
        return idxs, q_hat, bits

    def step_up(self, ctx, state, payload, priors):
        _, q_hat, bits = self._transmit(ctx, payload, priors)
        return q_hat, bits, state

    # -- wire codec --------------------------------------------------------

    def encode_up(self, ctx, state, payload, priors):
        idxs, q_hat, bits = self._transmit(ctx, payload, priors)
        idxs = np.asarray(idxs)  # (n_act, n_samples, n_seg)
        msgs = []
        for j, cid in enumerate(np.asarray(ctx.active)):
            w = BitWriter()
            wcodecs.put_indices(w, idxs[j], self.n_is)
            msgs.append(_wire_msg(DIR_UP, cid, SERVER, w))
        return q_hat, bits, state, msgs

    def decode_up(self, ctx, msgs, priors):
        plan, kt = ctx.plan, ctx.key
        seg = jnp.asarray(plan.seg_ids)
        shape = (self.n_samples, plan.n_blocks)
        idxs = []
        for m in msgs:
            r = _wire_reader(m)
            idxs.append(wcodecs.get_indices(r, shape, self.n_is))
            r.expect_exhausted()
        idxs = jnp.asarray(np.stack(idxs))
        if self.shared:
            q_hat = jax.vmap(lambda idx, p: mrc.receive_segments(
                kt, idx, clip01(p), seg, n_is=self.n_is))(idxs, priors)
        else:
            skeys = _vclient_keys(kt, ctx.active_ids)
            q_hat = jax.vmap(lambda k, idx, p: mrc.receive_segments(
                k, idx, clip01(p), seg, n_is=self.n_is))(skeys, idxs, priors)
        return q_hat


@dataclass
class QuantizedMRCUplink(StatelessUplink):
    """Conventional-FL uplink: stochastic sign -> MRC vs the Ber(1/2) prior.

    Each client maps its delta to a Bernoulli posterior q = sigmoid(delta/K)
    with per-client temperature K = mean|delta| (32-bit side information),
    conveys ``n_samples`` MRC samples against the uninformative prior, and
    the server reconstructs the direction (2*q_hat - 1) * K.
    """

    n_is: int = 256
    n_samples: int = 1
    chunk: int = 16
    logw_fn: Any = None
    side_info_bits: float = FLOAT_BITS

    def _transmit(self, ctx, payload, priors):
        plan = ctx.plan
        kt = ctx.key
        d = ctx.d
        p_blocks = jnp.full((plan.n_blocks, plan.size), 0.5, jnp.float32)
        sels = _vfold(jax.random.fold_in(kt, TAG_UL_SELECT), ctx.active_ids)

        # Each K fans into the posterior and the reconstruction rescale; pin
        # the vector so the fused engine rounds like the host loop.
        Ks = pin(ctx.pin_token,
                 jax.vmap(lambda delta: jnp.mean(jnp.abs(delta)) + 1e-12)(payload))

        def one(sel, delta, K):
            q_i = clip01(jax.nn.sigmoid(delta / K))
            idx, q_hat_b = mrc.transmit_fixed(
                kt, sel, to_blocks(q_i, plan.size), p_blocks, n_is=self.n_is,
                n_samples=self.n_samples, chunk=self.chunk, logw_fn=self.logw_fn)
            return idx, (2.0 * from_blocks(q_hat_b, d) - 1.0) * K

        idxs, g_hat = jax.vmap(one)(sels, payload, Ks)
        bits = ctx.n_active * (self.n_samples * plan.billable * math.log2(self.n_is)
                               + self.side_info_bits)
        return idxs, Ks, g_hat, bits

    def step_up(self, ctx, state, payload, priors):
        _, _, g_hat, bits = self._transmit(ctx, payload, priors)
        return g_hat, bits, state

    # -- wire codec --------------------------------------------------------
    # Payload per client: the f32 temperature K (the booked 32-bit side
    # information), then the MRC index stream.

    def encode_up(self, ctx, state, payload, priors):
        if self.side_info_bits != FLOAT_BITS:
            raise NotImplementedError(
                "wire codec encodes K as one f32; side_info_bits="
                f"{self.side_info_bits} cannot be serialized at that rate")
        idxs, Ks, g_hat, bits = self._transmit(ctx, payload, priors)
        idxs, Ks = np.asarray(idxs), np.asarray(Ks)
        msgs = []
        for j, cid in enumerate(np.asarray(ctx.active)):
            w = BitWriter()
            w.write_f32(Ks[j])
            wcodecs.put_indices(w, idxs[j], self.n_is)
            msgs.append(_wire_msg(DIR_UP, cid, SERVER, w))
        return g_hat, bits, state, msgs

    def decode_up(self, ctx, msgs, priors):
        plan, kt, d = ctx.plan, ctx.key, ctx.d
        p_blocks = jnp.full((plan.n_blocks, plan.size), 0.5, jnp.float32)
        shape = (self.n_samples, plan.n_blocks)
        Ks, idxs = [], []
        for m in msgs:
            r = _wire_reader(m)
            Ks.append(r.read_f32())
            idxs.append(wcodecs.get_indices(r, shape, self.n_is))
            r.expect_exhausted()
        Ks = jnp.asarray(np.stack(Ks))
        idxs = jnp.asarray(np.stack(idxs))

        def one(idx, K):
            q_hat_b = mrc.receive_fixed(kt, idx, p_blocks, n_is=self.n_is)
            return (2.0 * from_blocks(q_hat_b, d) - 1.0) * K

        return jax.vmap(one)(idxs, Ks)


# ---------------------------------------------------------------------------
# BiCompFL downlinks.
# ---------------------------------------------------------------------------


@dataclass
class IndexRelayDownlink(StatelessDownlink):
    """GR downlink: relay the other clients' uplink indices.

    With common candidates every client reconstructs the identical global
    model, so no recomputation is needed -- only the bits are booked:
    each client receives the (n-1) other clients' index streams (plus
    optional per-client side information, e.g. the CFL temperatures).
    """

    n_is: int = 256
    n_samples: int = 1           # relayed samples per client (n_UL)
    side_info_bits: float = 0.0
    broadcast_shareable: bool = True

    def step_down(self, ctx, state, update, theta, theta_hat):
        n = ctx.n_clients
        th = update.theta
        bits = n * (n - 1) * (self.n_samples * ctx.plan.billable
                              * math.log2(self.n_is) + self.side_info_bits)
        return DownlinkResult(th, jnp.tile(th[None], (n, 1)), bits), state

    # -- wire codec --------------------------------------------------------
    # The relay's payloads ARE the uplink payloads: each client receives the
    # (n-1) other clients' frames verbatim (for CFL those frames already
    # carry the K side information the channel books).

    def encode_down(self, ctx, state, update, theta, theta_hat, up_msgs):
        res, state = self.step_down(ctx, state, update, theta, theta_hat)
        if len(up_msgs) != ctx.n_clients:
            raise ValueError("index relay needs every client's uplink frame")
        msgs = []
        for rcpt in np.asarray(ctx.active):
            for m in up_msgs:
                if m.sender == int(rcpt):
                    continue
                msgs.append(Message(direction=DIR_DOWN, sender=m.sender,
                                    recipient=int(rcpt), payload=m.payload,
                                    payload_bits=m.payload_bits))
        return res, state, msgs

    def decode_down(self, ctx, msgs, theta, theta_hat, env: WireEnv):
        """Reconstruct through the *first* client's receive path: its own
        transmission plus the n-1 relays, decoded with the shared uplink
        codec and re-aggregated -- with common candidates this must land on
        exactly the server's model."""
        n = ctx.n_clients
        ref = int(np.asarray(ctx.active)[0])
        by_sender = {m.sender: m for m in msgs if m.recipient == ref}
        ordered = []
        for cid in np.asarray(ctx.active):
            if int(cid) == ref:
                own = [m for m in env.up_msgs if m.sender == ref]
                ordered.append(own[0])
            else:
                ordered.append(by_sender[int(cid)])
        up_out = env.uplink.decode_up(ctx, ordered, env.priors)
        th = env.aggregator(ctx, theta, up_out).theta
        bits = n * (n - 1) * (self.n_samples * ctx.plan.billable
                              * math.log2(self.n_is) + self.side_info_bits)
        return DownlinkResult(th, jnp.tile(th[None], (n, 1)), bits)


@dataclass
class MRCBroadcastDownlink(StatelessDownlink):
    """GR-Reconst downlink: one MRC re-transmission against the common prior;
    all clients share candidates and end with the same (noisy) estimate."""

    n_is: int = 256
    n_samples: int = 1           # n_DL
    chunk: int = 16
    logw_fn: Any = None
    seg_logw_fn: Any = None
    broadcast_shareable: bool = True

    def _transmit(self, ctx, update, theta_hat):
        kt, plan, d = ctx.key, ctx.plan, ctx.d
        skey = jax.random.fold_in(kt, TAG_DL_SHARED)
        sel = jax.random.fold_in(kt, TAG_DL_SELECT_COMMON)
        p_common = clip01(theta_hat[0])
        tgt = update.theta
        if plan.adaptive:
            idxs, est = mrc.transmit_segments(
                skey, sel, tgt, p_common, jnp.asarray(plan.seg_ids),
                n_is=self.n_is, n_seg=plan.n_blocks, n_samples=self.n_samples,
                seg_logw_fn=self.seg_logw_fn)
        else:
            idxs, est_b = mrc.transmit_fixed(
                skey, sel, to_blocks(tgt, plan.size), to_blocks(p_common, plan.size),
                n_is=self.n_is, n_samples=self.n_samples, chunk=self.chunk,
                logw_fn=self.logw_fn)
            est = from_blocks(est_b, d)
        bits = ctx.n_clients * self.n_samples * plan.billable * math.log2(self.n_is)
        return idxs, est, bits

    def step_down(self, ctx, state, update, theta, theta_hat):
        _, est, bits = self._transmit(ctx, update, theta_hat)
        return DownlinkResult(
            update.theta, jnp.tile(clip01(est)[None], (ctx.n_clients, 1)),
            bits), state

    # -- wire codec --------------------------------------------------------
    # One index stream, broadcast: n frames with identical payload (the
    # channel bills per client, so the stream totals match by construction).

    def encode_down(self, ctx, state, update, theta, theta_hat, up_msgs):
        idxs, est, bits = self._transmit(ctx, update, theta_hat)
        w = BitWriter()
        wcodecs.put_indices(w, np.asarray(idxs), self.n_is)
        payload, nbits = w.getvalue(), w.bits_written
        msgs = [Message(direction=DIR_DOWN, sender=SERVER, recipient=int(cid),
                        payload=payload, payload_bits=nbits)
                for cid in np.asarray(ctx.active)]
        res = DownlinkResult(
            update.theta, jnp.tile(clip01(est)[None], (ctx.n_clients, 1)),
            bits)
        return res, state, msgs

    def decode_down(self, ctx, msgs, theta, theta_hat, env: WireEnv):
        kt, plan, d = ctx.key, ctx.plan, ctx.d
        skey = jax.random.fold_in(kt, TAG_DL_SHARED)
        r = _wire_reader(msgs[0])
        idxs = wcodecs.get_indices(
            r, (self.n_samples, plan.n_blocks), self.n_is)
        r.expect_exhausted()
        idxs = jnp.asarray(idxs)
        p_common = clip01(theta_hat[0])
        if plan.adaptive:
            est = mrc.receive_segments(skey, idxs, p_common,
                                       jnp.asarray(plan.seg_ids),
                                       n_is=self.n_is)
        else:
            est_b = mrc.receive_fixed(skey, idxs,
                                      to_blocks(p_common, plan.size),
                                      n_is=self.n_is)
            est = from_blocks(est_b, d)
        bits = ctx.n_clients * self.n_samples * plan.billable * math.log2(self.n_is)
        return DownlinkResult(
            env.update.theta,
            jnp.tile(clip01(est)[None], (ctx.n_clients, 1)), bits)


@dataclass
class MRCPrivateDownlink(StatelessDownlink):
    """PR downlink: per-client MRC against each client's own prior, vmapped
    over per-client private keys.  Under partial participation only the
    active cohort receives the downlink; stragglers keep stale estimates."""

    n_is: int = 256
    n_samples: int = 1           # n_DL
    chunk: int = 16
    logw_fn: Any = None
    seg_logw_fn: Any = None
    broadcast_shareable: bool = False
    downlink_recipients = "active"  # client-specific payloads, cohort only

    def _transmit(self, ctx, update, theta_hat):
        kt, plan, d = ctx.key, ctx.plan, ctx.d
        ids = ctx.active_ids
        skeys = jax.vmap(lambda k: jax.random.fold_in(k, TAG_DL_SHARED))(
            _vclient_keys(kt, ids))
        sels = _vfold(jax.random.fold_in(kt, TAG_DL_SELECT_PRIVATE), ids)
        priors = clip01(theta_hat[ids])
        tgt = update.theta
        if plan.adaptive:
            seg = jnp.asarray(plan.seg_ids)

            def one(skey, sel, p_i):
                return mrc.transmit_segments(
                    skey, sel, tgt, p_i, seg, n_is=self.n_is,
                    n_seg=plan.n_blocks, n_samples=self.n_samples,
                    seg_logw_fn=self.seg_logw_fn)
        else:
            tb = to_blocks(tgt, plan.size)

            def one(skey, sel, p_i):
                idx, est_b = mrc.transmit_fixed(
                    skey, sel, tb, to_blocks(p_i, plan.size), n_is=self.n_is,
                    n_samples=self.n_samples, chunk=self.chunk, logw_fn=self.logw_fn)
                return idx, from_blocks(est_b, d)

        idxs, est = jax.vmap(one)(skeys, sels, priors)
        bits = ctx.n_active * self.n_samples * plan.billable * math.log2(self.n_is)
        return idxs, est, bits

    def step_down(self, ctx, state, update, theta, theta_hat):
        _, est, bits = self._transmit(ctx, update, theta_hat)
        theta_hat = theta_hat.at[ctx.active_ids].set(clip01(est))
        return DownlinkResult(update.theta, theta_hat, bits), state

    # -- wire codec --------------------------------------------------------

    def encode_down(self, ctx, state, update, theta, theta_hat, up_msgs):
        idxs, est, bits = self._transmit(ctx, update, theta_hat)
        idxs = np.asarray(idxs)  # (n_act, n_samples, B)
        msgs = []
        for j, cid in enumerate(np.asarray(ctx.active)):
            w = BitWriter()
            wcodecs.put_indices(w, idxs[j], self.n_is)
            msgs.append(_wire_msg(DIR_DOWN, SERVER, cid, w))
        new_hat = theta_hat.at[ctx.active_ids].set(clip01(est))
        return DownlinkResult(update.theta, new_hat, bits), state, msgs

    def decode_down(self, ctx, msgs, theta, theta_hat, env: WireEnv):
        kt, plan, d = ctx.key, ctx.plan, ctx.d
        ids = ctx.active_ids
        skeys = jax.vmap(lambda k: jax.random.fold_in(k, TAG_DL_SHARED))(
            _vclient_keys(kt, ids))
        priors = clip01(theta_hat[ids])
        shape = (self.n_samples, plan.n_blocks)
        idxs = []
        for m in msgs:
            r = _wire_reader(m)
            idxs.append(wcodecs.get_indices(r, shape, self.n_is))
            r.expect_exhausted()
        idxs = jnp.asarray(np.stack(idxs))
        if plan.adaptive:
            seg = jnp.asarray(plan.seg_ids)
            est = jax.vmap(lambda k, idx, p: mrc.receive_segments(
                k, idx, p, seg, n_is=self.n_is))(skeys, idxs, priors)
        else:
            est = jax.vmap(lambda k, idx, p: from_blocks(mrc.receive_fixed(
                k, idx, to_blocks(p, plan.size), n_is=self.n_is), d))(
                    skeys, idxs, priors)
        new_hat = theta_hat.at[ids].set(clip01(est))
        bits = ctx.n_active * self.n_samples * plan.billable * math.log2(self.n_is)
        return DownlinkResult(env.update.theta, new_hat, bits)


@dataclass
class SplitBlockDownlink(StatelessDownlink):
    """PR-SplitDL: each client receives MRC only for a disjoint 1/n of the
    blocks (downlink cost / n); the rest of its estimate stays as-is.

    Clients own interleaved block subsets arange(i, B, n).  The per-client
    subsets are ragged when B % n != 0, so they are padded to the common
    maximum with one sentinel block whose result is discarded -- this keeps
    the whole downlink a single vmapped transmission.  Fixed blocks only.
    """

    n_is: int = 256
    n_samples: int = 1           # n_DL
    chunk: int = 16
    logw_fn: Any = None
    broadcast_shareable: bool = False

    @staticmethod
    def _ownership(n: int, n_blocks: int):
        """Padded interleaved block-ownership table and its sentinel row."""
        max_len = -(-n_blocks // n)
        own_pad = np.full((n, max_len), n_blocks, np.int32)
        for i in range(n):
            own = np.arange(i, n_blocks, n, dtype=np.int32)
            own_pad[i, :len(own)] = own
        return jnp.asarray(own_pad), max_len

    def _transmit(self, ctx, update, theta_hat):
        kt, plan, d = ctx.key, ctx.plan, ctx.d
        if plan.adaptive:
            raise NotImplementedError("SplitDL is defined on fixed blocks")
        n, size, n_blocks = ctx.n_clients, plan.size, plan.n_blocks
        # Sentinel index n_blocks targets a dummy row.
        own_pad, max_len = self._ownership(n, n_blocks)

        tb = to_blocks(update.theta, size)                       # (B, S)
        dummy = jnp.full((1, size), 0.5, tb.dtype)
        tb_ext = jnp.concatenate([tb, dummy])
        hb_all = to_blocks(clip01(theta_hat), size)              # (n, B, S)
        ids = jnp.arange(n, dtype=jnp.int32)
        skeys = jax.vmap(lambda k: jax.random.fold_in(k, TAG_DL_SHARED))(
            _vclient_keys(kt, ids))
        sels = _vfold(jax.random.fold_in(kt, TAG_DL_SELECT_PRIVATE), ids)
        chunk = min(self.chunk, max_len)

        def one(skey, sel, hb_i, own_i):
            hb_ext = jnp.concatenate([hb_i, dummy])
            idx, est_b = mrc.transmit_fixed(
                skey, sel, tb_ext[own_i], hb_ext[own_i], n_is=self.n_is,
                n_samples=self.n_samples, chunk=chunk, logw_fn=self.logw_fn)
            hb_ext = hb_ext.at[own_i].set(clip01(est_b))
            return idx, from_blocks(hb_ext[:n_blocks], d)

        idxs, theta_hat = jax.vmap(one)(skeys, sels, hb_all, own_pad)
        bits = n * self.n_samples * max_len * math.log2(self.n_is)
        return idxs, theta_hat, bits

    def step_down(self, ctx, state, update, theta, theta_hat):
        _, theta_hat, bits = self._transmit(ctx, update, theta_hat)
        return DownlinkResult(update.theta, theta_hat, bits), state

    # -- wire codec --------------------------------------------------------
    # Per client: indices for its (padded) owned-block subset, sentinel
    # included -- the channel bills the padding, so the wire carries it.

    def encode_down(self, ctx, state, update, theta, theta_hat, up_msgs):
        idxs, new_hat, bits = self._transmit(ctx, update, theta_hat)
        idxs = np.asarray(idxs)  # (n, n_samples, max_len)
        msgs = []
        for j, cid in enumerate(np.asarray(ctx.active)):
            w = BitWriter()
            wcodecs.put_indices(w, idxs[j], self.n_is)
            msgs.append(_wire_msg(DIR_DOWN, SERVER, cid, w))
        return DownlinkResult(update.theta, new_hat, bits), state, msgs

    def decode_down(self, ctx, msgs, theta, theta_hat, env: WireEnv):
        kt, plan, d = ctx.key, ctx.plan, ctx.d
        n, size, n_blocks = ctx.n_clients, plan.size, plan.n_blocks
        own_pad, max_len = self._ownership(n, n_blocks)
        dummy = jnp.full((1, size), 0.5, jnp.float32)
        hb_all = to_blocks(clip01(theta_hat), size)
        ids = jnp.arange(n, dtype=jnp.int32)
        skeys = jax.vmap(lambda k: jax.random.fold_in(k, TAG_DL_SHARED))(
            _vclient_keys(kt, ids))
        shape = (self.n_samples, max_len)
        idxs = []
        for m in msgs:
            r = _wire_reader(m)
            idxs.append(wcodecs.get_indices(r, shape, self.n_is))
            r.expect_exhausted()
        idxs = jnp.asarray(np.stack(idxs))

        def one(skey, idx, hb_i, own_i):
            hb_ext = jnp.concatenate([hb_i, dummy])
            est_b = mrc.receive_fixed(skey, idx, hb_ext[own_i],
                                      n_is=self.n_is)
            hb_ext = hb_ext.at[own_i].set(clip01(est_b))
            return from_blocks(hb_ext[:n_blocks], d)

        new_hat = jax.vmap(one)(skeys, idxs, hb_all, own_pad)
        bits = n * self.n_samples * max_len * math.log2(self.n_is)
        return DownlinkResult(env.update.theta, new_hat, bits)


# ---------------------------------------------------------------------------
# Non-stochastic baseline channels.
# ---------------------------------------------------------------------------


@dataclass
class DenseChannel(StatelessUplink, StatelessDownlink):
    """Lossless 32-bit transmission; usable on either direction."""

    bits_per_value: float = FLOAT_BITS
    broadcast_shareable: bool = True

    def step_up(self, ctx, state, payload, priors):
        return payload, ctx.n_active * ctx.d * self.bits_per_value, state

    def step_down(self, ctx, state, update, theta, theta_hat):
        th = update.theta
        return DownlinkResult(th, jnp.tile(th[None], (ctx.n_clients, 1)),
                              ctx.n_clients * ctx.d * self.bits_per_value), state

    def flush_step(self, state, n, d):
        # Stateless: a periodic sync through a dense channel only costs bits.
        return 0.0, n * d * self.bits_per_value, state

    def flush(self, n, d):
        return 0.0, n * d * self.bits_per_value

    # -- wire codec: raw big-endian f32 vectors ----------------------------

    def _check_rate(self):
        if self.bits_per_value != FLOAT_BITS:
            raise NotImplementedError(
                f"dense wire codec is f32-only ({self.bits_per_value} "
                "bits/value requested)")

    def encode_up(self, ctx, state, payload, priors):
        self._check_rate()
        rows = np.asarray(payload)
        msgs = []
        for j, cid in enumerate(np.asarray(ctx.active)):
            w = BitWriter()
            wcodecs.put_dense(w, rows[j])
            msgs.append(_wire_msg(DIR_UP, cid, SERVER, w))
        return payload, ctx.n_active * ctx.d * self.bits_per_value, state, msgs

    def decode_up(self, ctx, msgs, priors):
        rows = []
        for m in msgs:
            r = _wire_reader(m)
            rows.append(wcodecs.get_dense(r, ctx.d))
            r.expect_exhausted()
        return jnp.asarray(np.stack(rows))

    def encode_down(self, ctx, state, update, theta, theta_hat, up_msgs):
        self._check_rate()
        res, state = self.step_down(ctx, state, update, theta, theta_hat)
        w = BitWriter()
        wcodecs.put_dense(w, np.asarray(update.theta))
        payload, nbits = w.getvalue(), w.bits_written
        msgs = [Message(direction=DIR_DOWN, sender=SERVER, recipient=int(cid),
                        payload=payload, payload_bits=nbits)
                for cid in range(ctx.n_clients)]
        return res, state, msgs

    def decode_down(self, ctx, msgs, theta, theta_hat, env: WireEnv):
        r = _wire_reader(msgs[0])
        th = jnp.asarray(wcodecs.get_dense(r, ctx.d))
        r.expect_exhausted()
        return DownlinkResult(th, jnp.tile(th[None], (ctx.n_clients, 1)),
                              ctx.n_clients * ctx.d * self.bits_per_value)

    def flush_wire(self, n, d):
        # Dense channels hold no EF memory: the sync uplink is the zero
        # residual, serialized at the billed dense rate.
        self._check_rate()
        r, bits = self.flush(n, d)
        msgs = []
        for cid in range(n):
            w = BitWriter()
            wcodecs.put_dense(w, np.zeros(d, np.float32))
            msgs.append(_wire_msg(DIR_FLUSH_UP, cid, SERVER, w))
        return r, bits, msgs

    def decode_flush_up(self, msgs, n, d):
        rows = [wcodecs.get_dense(_wire_reader(m), d) for m in msgs]
        return jnp.mean(jnp.asarray(np.stack(rows)), axis=0)


@dataclass
class SignEFChannel:
    """Sign compression with error feedback; ``passes>1`` repeats compression
    on the residual (Neolithic's R-pass scheme, ~``passes`` bits/param).

    As an uplink it keeps per-client EF memory (n, d); as a downlink it
    keeps the server-side memory (d,) and steps server *and* clients with
    the compressed aggregate (DoubleSqueeze).
    """

    passes: int = 1
    broadcast_shareable: bool = True
    downlink_recipients = "all"
    _e: Optional[jax.Array] = field(default=None, repr=False)

    def _compress_passes(self, v):
        """Iterated sign compression, also yielding the per-pass wire
        payload: (scale, sign-bit vector) per pass.  The reconstruction
        ``sum_r scale_r * (+-1)`` is exactly what ``_compress`` computes
        (``sign_compress`` is scale * where(v >= 0, 1, -1))."""
        comps = []
        c = None
        resid = v
        for _ in range(self.passes):
            scale = jnp.mean(jnp.abs(resid))
            sgn = resid >= 0
            step = sign_compress(resid)  # == scale * where(sgn, 1, -1)
            c = step if c is None else c + step
            resid = v - c
            comps.append((scale, sgn))
        return c, comps

    def _compress(self, v):
        c, _ = self._compress_passes(v)
        return c

    # -- functional core --------------------------------------------------
    def init_up_state(self, n, d):
        return jnp.zeros((n, d), jnp.float32)

    def init_down_state(self, n, d):
        return jnp.zeros((d,), jnp.float32)

    def step_up(self, ctx, e, payload, priors):
        if ctx.n_active != ctx.n_clients:
            raise ValueError("error-feedback uplinks require full participation")
        acc = payload + e
        c = jax.vmap(self._compress)(acc)
        bits = ctx.n_clients * self.passes * (ctx.d + FLOAT_BITS)
        return c, bits, acc - c

    def step_down(self, ctx, e, update, theta, theta_hat):
        g = update.delta if update.delta is not None \
            else (theta - update.theta) / update.lr
        agg = g + e
        c_s = self._compress(agg)
        bits = ctx.n_clients * self.passes * (ctx.d + FLOAT_BITS)
        return DownlinkResult(theta - update.lr * c_s,
                              theta_hat - update.lr * c_s[None, :], bits), agg - c_s

    def flush_step(self, e, n, d):
        r = jnp.mean(e, axis=0) if e.ndim == 2 else e
        return r, n * d * FLOAT_BITS, jnp.zeros_like(e)

    # -- wire codec --------------------------------------------------------
    # Per client (uplink) / broadcast (downlink): ``passes`` records of one
    # f32 scale + a d-bit sign bitmap -- the booked passes * (d + 32).

    def _decode_compressed(self, r, d):
        c = None
        for _ in range(self.passes):
            scale, sgn = wcodecs.get_sign_pass(r, d)
            step = jnp.float32(scale) * jnp.where(jnp.asarray(sgn), 1.0, -1.0)
            c = step if c is None else c + step
        return c

    def encode_up(self, ctx, e, payload, priors):
        if ctx.n_active != ctx.n_clients:
            raise ValueError("error-feedback uplinks require full participation")
        acc = payload + e
        c, comps = jax.vmap(self._compress_passes)(acc)
        bits = ctx.n_clients * self.passes * (ctx.d + FLOAT_BITS)
        msgs = []
        for j, cid in enumerate(np.asarray(ctx.active)):
            w = BitWriter()
            for scale, sgn in comps:
                wcodecs.put_sign_pass(w, np.asarray(scale)[j],
                                      np.asarray(sgn)[j])
            msgs.append(_wire_msg(DIR_UP, cid, SERVER, w))
        return c, bits, acc - c, msgs

    def decode_up(self, ctx, msgs, priors):
        rows = []
        for m in msgs:
            r = _wire_reader(m)
            rows.append(self._decode_compressed(r, ctx.d))
            r.expect_exhausted()
        return jnp.stack(rows)

    def encode_down(self, ctx, e, update, theta, theta_hat, up_msgs):
        g = update.delta if update.delta is not None \
            else (theta - update.theta) / update.lr
        agg = g + e
        c_s, comps = self._compress_passes(agg)
        bits = ctx.n_clients * self.passes * (ctx.d + FLOAT_BITS)
        w = BitWriter()
        for scale, sgn in comps:
            wcodecs.put_sign_pass(w, np.asarray(scale), np.asarray(sgn))
        payload, nbits = w.getvalue(), w.bits_written
        msgs = [Message(direction=DIR_DOWN, sender=SERVER, recipient=int(cid),
                        payload=payload, payload_bits=nbits)
                for cid in range(ctx.n_clients)]
        res = DownlinkResult(theta - update.lr * c_s,
                             theta_hat - update.lr * c_s[None, :], bits)
        return res, agg - c_s, msgs

    def decode_down(self, ctx, msgs, theta, theta_hat, env: WireEnv):
        r = _wire_reader(msgs[0])
        c_s = self._decode_compressed(r, ctx.d)
        r.expect_exhausted()
        lr = env.update.lr
        bits = ctx.n_clients * self.passes * (ctx.d + FLOAT_BITS)
        return DownlinkResult(theta - lr * c_s,
                              theta_hat - lr * c_s[None, :], bits)

    # -- object shell ------------------------------------------------------
    def transmit(self, ctx, payload, priors):
        if self._e is None:
            self._e = jnp.zeros_like(payload)
        out, bits, self._e = self.step_up(ctx, self._e, payload, priors)
        return out, bits

    def transmit_wire(self, ctx, payload, priors):
        if self._e is None:
            self._e = jnp.zeros_like(payload)
        out, bits, self._e, msgs = self.encode_up(ctx, self._e, payload,
                                                  priors)
        return out, bits, msgs

    def distribute(self, ctx, update, theta, theta_hat):
        if self._e is None:
            self._e = jnp.zeros_like(theta)
        res, self._e = self.step_down(ctx, self._e, update, theta, theta_hat)
        return res

    def distribute_wire(self, ctx, update, theta, theta_hat, up_msgs):
        if self._e is None:
            self._e = jnp.zeros_like(theta)
        res, self._e, msgs = self.encode_down(ctx, self._e, update, theta,
                                              theta_hat, up_msgs)
        return res, msgs

    def flush(self, n, d):
        if self._e is None:
            return 0.0, n * d * FLOAT_BITS
        r, bits, self._e = self.flush_step(self._e, n, d)
        return r, bits

    def flush_wire(self, n, d):
        """Uplink EF sync: every client uploads its dense residual row."""
        e = self._e if self._e is not None else jnp.zeros((n, d), jnp.float32)
        rows = np.asarray(e if e.ndim == 2 else jnp.tile(e[None], (n, 1)))
        msgs = []
        for cid in range(n):
            w = BitWriter()
            wcodecs.put_dense(w, rows[cid])
            msgs.append(_wire_msg(DIR_FLUSH_UP, cid, SERVER, w))
        r, bits = self.flush(n, d)
        return r, bits, msgs

    def decode_flush_up(self, msgs, n, d):
        rows = [wcodecs.get_dense(_wire_reader(m), d) for m in msgs]
        return jnp.mean(jnp.asarray(np.stack(rows)), axis=0)

    def export_state(self):
        return self._e

    def import_state(self, state) -> None:
        self._e = state

    def reset(self):
        self._e = None


@dataclass
class TopKEFChannel:
    """Top-k sparsification with error feedback (M3 uplink, k = d/n)."""

    k: int = 1
    _e: Optional[jax.Array] = field(default=None, repr=False)

    # -- functional core --------------------------------------------------
    def init_up_state(self, n, d):
        return jnp.zeros((n, d), jnp.float32)

    def step_up(self, ctx, e, payload, priors):
        if ctx.n_active != ctx.n_clients:
            raise ValueError("error-feedback uplinks require full participation")
        acc = payload + e
        c = jax.vmap(lambda v: topk_compress(v, self.k))(acc)
        return c, ctx.n_clients * topk_bits(ctx.d, self.k), acc - c

    def flush_step(self, e, n, d):
        return jnp.mean(e, axis=0), n * d * FLOAT_BITS, jnp.zeros_like(e)

    # -- wire codec --------------------------------------------------------
    # Per client: k records of (ceil(log2 d)-bit index, f32 value) -- the
    # booked topk_bits(d, k).

    def encode_up(self, ctx, e, payload, priors):
        if ctx.n_active != ctx.n_clients:
            raise ValueError("error-feedback uplinks require full participation")
        acc = payload + e
        kk = min(self.k, ctx.d)
        _, idxs = jax.vmap(lambda v: jax.lax.top_k(jnp.abs(v), kk))(acc)
        vals = jnp.take_along_axis(acc, idxs, axis=1)
        c = jax.vmap(lambda v: topk_compress(v, self.k))(acc)
        bits = ctx.n_clients * topk_bits(ctx.d, self.k)
        msgs = []
        for j, cid in enumerate(np.asarray(ctx.active)):
            w = BitWriter()
            wcodecs.put_topk(w, np.asarray(idxs)[j], np.asarray(vals)[j],
                             ctx.d)
            msgs.append(_wire_msg(DIR_UP, cid, SERVER, w))
        return c, bits, acc - c, msgs

    def decode_up(self, ctx, msgs, priors):
        kk = min(self.k, ctx.d)
        rows = []
        for m in msgs:
            r = _wire_reader(m)
            idx, vals = wcodecs.get_topk(r, kk, ctx.d)
            r.expect_exhausted()
            rows.append(jnp.zeros(ctx.d, jnp.float32)
                        .at[jnp.asarray(idx)].set(jnp.asarray(vals)))
        return jnp.stack(rows)

    # -- object shell ------------------------------------------------------
    def transmit(self, ctx, payload, priors):
        if self._e is None:
            self._e = jnp.zeros_like(payload)
        out, bits, self._e = self.step_up(ctx, self._e, payload, priors)
        return out, bits

    def transmit_wire(self, ctx, payload, priors):
        if self._e is None:
            self._e = jnp.zeros_like(payload)
        out, bits, self._e, msgs = self.encode_up(ctx, self._e, payload,
                                                  priors)
        return out, bits, msgs

    def flush(self, n, d):
        if self._e is None:
            return 0.0, n * d * FLOAT_BITS
        r, bits, self._e = self.flush_step(self._e, n, d)
        return r, bits

    def flush_wire(self, n, d):
        e = self._e if self._e is not None else jnp.zeros((n, d), jnp.float32)
        rows = np.asarray(e)
        msgs = []
        for cid in range(n):
            w = BitWriter()
            wcodecs.put_dense(w, rows[cid])
            msgs.append(_wire_msg(DIR_FLUSH_UP, cid, SERVER, w))
        r, bits = self.flush(n, d)
        return r, bits, msgs

    def decode_flush_up(self, msgs, n, d):
        rows = [wcodecs.get_dense(_wire_reader(m), d) for m in msgs]
        return jnp.mean(jnp.asarray(np.stack(rows)), axis=0)

    def export_state(self):
        return self._e

    def import_state(self, state) -> None:
        self._e = state

    def reset(self):
        self._e = None


@dataclass
class SliceDownlink(StatelessDownlink):
    """M3 downlink: each client receives a disjoint dense 1/n model slice;
    client estimates diverge (no broadcast saving possible).

    ``k`` (slice width) defaults to d/n at runtime; pass it explicitly to
    keep it consistent with a paired Top-k uplink budget."""

    k: Optional[int] = None
    broadcast_shareable: bool = False

    def step_down(self, ctx, state, update, theta, theta_hat):
        n, d = ctx.n_clients, ctx.d
        th = update.theta
        k = self.k if self.k is not None else max(d // n, 1)
        new_hat = []
        for i in range(n):
            lo = i * k
            hi = d if i == n - 1 else min((i + 1) * k, d)
            new_hat.append(theta_hat[i].at[lo:hi].set(th[lo:hi]))
        return DownlinkResult(th, jnp.stack(new_hat),
                              n * (d / n) * FLOAT_BITS), state

    # -- wire codec --------------------------------------------------------
    # Client i's message carries its dense f32 slice [i*k, hi); the slices
    # tile [0, d) so the stream totals d * 32 bits == the booked
    # n * (d/n) * 32 up to float round-off (cf. RECONCILE_REL_TOL).

    def _bounds(self, n, d):
        k = self.k if self.k is not None else max(d // n, 1)
        out = []
        for i in range(n):
            lo = i * k
            hi = d if i == n - 1 else min((i + 1) * k, d)
            out.append((lo, hi))
        return out

    def encode_down(self, ctx, state, update, theta, theta_hat, up_msgs):
        res, state = self.step_down(ctx, state, update, theta, theta_hat)
        th = np.asarray(res.theta)
        msgs = []
        for cid, (lo, hi) in enumerate(self._bounds(ctx.n_clients, ctx.d)):
            w = BitWriter()
            wcodecs.put_dense(w, th[lo:hi])
            msgs.append(_wire_msg(DIR_DOWN, SERVER, cid, w))
        return res, state, msgs

    def decode_down(self, ctx, msgs, theta, theta_hat, env: WireEnv):
        n, d = ctx.n_clients, ctx.d
        by_recipient = {m.recipient: m for m in msgs}
        new_hat = []
        for cid, (lo, hi) in enumerate(self._bounds(n, d)):
            r = _wire_reader(by_recipient[cid])
            sl = wcodecs.get_dense(r, hi - lo)
            r.expect_exhausted()
            new_hat.append(theta_hat[cid].at[lo:hi].set(jnp.asarray(sl)))
        return DownlinkResult(env.update.theta, jnp.stack(new_hat),
                              n * (d / n) * FLOAT_BITS)
