"""Core BiCompFL machinery: MRC codec, quantizers, block allocation, bits."""
from . import bernoulli, bitmeter, blocks, mrc, quantizers  # noqa: F401
