"""Small pure-JAX classifier networks for the FL experiments.

Bias-free CNN/MLP families mirroring the paper's LeNet5 / 4CNN / 6CNN
(scaled to the synthetic datasets).  For probabilistic-mask training the
weights use the *signed-constant* initialization of Ramanujan et al. (2020):
w = sign(n) * std_kaiming -- the setting in which random subnetworks are
known to be expressive.
"""
from __future__ import annotations

import math
from typing import Callable, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


class Net(NamedTuple):
    init: Callable[[jax.Array], list]
    apply: Callable[[list, jax.Array], jax.Array]  # (weights, x NHWC) -> logits


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _kaiming_signed(key, shape, fan_in, signed_constant: bool):
    std = math.sqrt(2.0 / fan_in)
    w = jax.random.normal(key, shape)
    if signed_constant:
        return jnp.sign(w) * std
    return w * std


def make_cnn(
    hw: int = 14,
    channels: int = 1,
    n_classes: int = 10,
    conv_widths: Sequence[int] = (32, 64),
    dense_widths: Sequence[int] = (128,),
    signed_constant: bool = False,
) -> Net:
    """Conv(3x3)+ReLU+MaxPool blocks, then dense head. Bias-free."""
    n_pools = len(conv_widths)
    final_hw = hw // (2 ** n_pools)
    assert final_hw >= 1, "too many pools for input size"

    shapes: List[Tuple[Tuple[int, ...], int]] = []  # (shape, fan_in)
    cin = channels
    for w_ in conv_widths:
        shapes.append(((3, 3, cin, w_), 3 * 3 * cin))
        cin = w_
    flat = final_hw * final_hw * cin
    din = flat
    for w_ in dense_widths:
        shapes.append(((din, w_), din))
        din = w_
    shapes.append(((din, n_classes), din))

    def init(key):
        keys = jax.random.split(key, len(shapes))
        return [_kaiming_signed(k, s, f, signed_constant) for k, (s, f) in zip(keys, shapes)]

    n_conv = len(conv_widths)

    def apply(weights, x):
        h = x
        for i in range(n_conv):
            h = _maxpool(jax.nn.relu(_conv(h, weights[i])))
        h = h.reshape(h.shape[0], -1)
        for w_ in weights[n_conv:-1]:
            h = jax.nn.relu(h @ w_)
        return h @ weights[-1]

    return Net(init=init, apply=apply)


def make_mlp(
    in_dim: int, widths: Sequence[int] = (256, 256), n_classes: int = 10,
    signed_constant: bool = False,
) -> Net:
    dims = [in_dim, *widths, n_classes]

    def init(key):
        keys = jax.random.split(key, len(dims) - 1)
        return [
            _kaiming_signed(k, (a, b), a, signed_constant)
            for k, a, b in zip(keys, dims[:-1], dims[1:])
        ]

    def apply(weights, x):
        h = x.reshape(x.shape[0], -1)
        for w_ in weights[:-1]:
            h = jax.nn.relu(h @ w_)
        return h @ weights[-1]

    return Net(init=init, apply=apply)


def flatten_weights(weights) -> Tuple[jax.Array, Callable]:
    return ravel_pytree(weights)


def cross_entropy(logits: jax.Array, y: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(apply_fn, weights, x, y, batch: int = 1000) -> float:
    n = x.shape[0]
    correct = 0
    for i in range(0, n, batch):
        logits = apply_fn(weights, x[i : i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i : i + batch]))
    return correct / n
